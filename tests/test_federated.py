"""FaaS executor, FL orchestrator, steering, compression, fault tolerance."""
import os
import time

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import Store
from repro.core.connectors import FileConnector, SharedMemoryConnector
from repro.distributed.compression import Compressor
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               HeartbeatWriter, RetryPolicy,
                                               overprovision, with_retries)
from repro.federated.faas import CloudModel, FaasExecutor, PayloadTooLarge
from repro.federated.fl import FLConfig, FLOrchestrator
from repro.federated.steer import SteerConfig, Steering

TINY = ARCHS["phi4-mini-3.8b"].reduced().replace(
    n_layers=2, d_model=64, d_ff=128, vocab=128, dtype="float32")


@pytest.fixture(scope="module")
def executor():
    ex = FaasExecutor(n_workers=2, cloud=CloudModel(latency_s=0.001))
    yield ex
    ex.shutdown()


def test_faas_basic(executor):
    assert executor.submit(sum, [1, 2, 3]).result() == 6
    with pytest.raises(RuntimeError):
        executor.submit(int, "not-a-number").result()


def test_faas_payload_cap():
    ex = FaasExecutor(n_workers=1,
                      cloud=CloudModel(latency_s=0.0, payload_cap=10_000))
    try:
        with pytest.raises(PayloadTooLarge):
            ex.submit(len, b"x" * 100_000)
        # a proxy of the same data passes the cap
        assert ex.submit(len, b"x" * 100).result() == 100
    finally:
        ex.shutdown()


@pytest.mark.slow
def test_fl_round_learns(executor, tmp_path):
    store = Store("fl-t", FileConnector(str(tmp_path / "fl")))
    fl = FLConfig(rounds=2, workers_per_round=2, local_steps=3,
                  transport="proxy", deadline_s=120)
    orch = FLOrchestrator(TINY, fl, executor, store)
    res = orch.run()
    assert res["losses"][-1] < res["losses"][0]
    assert all(r["ok"] == 2 for r in res["rounds"])


@pytest.mark.slow
def test_fl_pipelined_futures_and_streams(executor, tmp_path):
    """pipeline=True: next-round weights are pre-data futures (workers
    park in wait) and updates come back as a stream, not a barrier-put."""
    from repro.core.connectors import KVServerConnector
    from repro.core.deploy import start_kvserver

    kv = start_kvserver(str(tmp_path))
    try:
        store = Store("fl-pipe", KVServerConnector(kv.host, kv.port))
        fl = FLConfig(rounds=2, workers_per_round=2, local_steps=3,
                      transport="proxy", pipeline=True, deadline_s=120)
        orch = FLOrchestrator(TINY, fl, executor, store,
                              monitor_group="monitor")
        res = orch.run()
        assert all(r["ok"] == 2 for r in res["rounds"])
        assert res["losses"][-1] < res["losses"][0]
        # the monitor group tailed the same updates the aggregator
        # consumed (ok == 2 above proves nothing was stolen): metadata
        # only, no update tensors resolved
        with orch.monitor_updates(0, timeout=5.0) as tap:
            metas = list(tap)
        assert len(metas) == 2 and all(m["ok"] for m in metas)
        store.close()
    finally:
        kv.stop()


@pytest.mark.slow
def test_fl_elastic_and_compression(executor, tmp_path):
    store = Store("fl-e", FileConnector(str(tmp_path / "fl")))
    fl = FLConfig(rounds=2, workers_per_round=2, local_steps=2,
                  transport="proxy", compression="int8", deadline_s=120)
    orch = FLOrchestrator(TINY, fl, executor, store)
    res = orch.run(worker_schedule=[1, 3])
    assert [r["workers"] for r in res["rounds"]] == [1, 3]
    assert res["losses"][-1] < res["losses"][0] + 0.01


def test_steering_proxy_reduces_server_traffic(tmp_path):
    rng = np.random.default_rng(0)
    payload = rng.standard_normal(300_000).astype(np.float32)
    store = Store("steer-t", SharedMemoryConnector(str(tmp_path / "shm")))
    s1 = Steering(SteerConfig(proxy_threshold=50_000), store)
    r1 = s1.run(lambda x: float(np.sum(x)), lambda i: payload, 4)
    s1.close()
    s2 = Steering(SteerConfig(proxy_threshold=None), None)
    r2 = s2.run(lambda x: float(np.sum(x)), lambda i: payload, 4)
    s2.close()
    assert r1["server_bytes"] < r2["server_bytes"] / 50
    assert sorted(r1["results"]) == pytest.approx(sorted(r2["results"]))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    tree = {"w": rng.standard_normal((64, 64)).astype(np.float32)}
    comp = Compressor("int8")
    out = Compressor.decompress(comp.compress(tree))
    scale = np.abs(tree["w"]).max() / 127
    assert np.abs(out["w"] - tree["w"]).max() <= scale * 0.51
    # 4x smaller on the wire
    assert Compressor.payload_bytes(comp.compress(tree)) < \
        tree["w"].nbytes / 2


def test_error_feedback_is_unbiased_over_time():
    rng = np.random.default_rng(2)
    g = rng.standard_normal((256,)).astype(np.float32) * 0.01
    comp = Compressor("int8_ef")
    acc = np.zeros_like(g)
    for _ in range(50):
        acc += Compressor.decompress(comp.compress({"g": g}))["g"]
    # mean of decompressed matches true gradient closely thanks to EF
    np.testing.assert_allclose(acc / 50, g, atol=2e-4)


def test_topk_keeps_largest():
    x = np.array([[0.1, -5.0, 0.2, 3.0]], np.float32)
    out = Compressor.decompress(Compressor("topk", topk_frac=0.5)
                                .compress({"x": x}))["x"]
    np.testing.assert_array_equal(out, [[0.0, -5.0, 0.0, 3.0]])


# ---------------------------------------------------------------------------
# fault-tolerance utilities
# ---------------------------------------------------------------------------
def test_heartbeats(tmp_path):
    w = HeartbeatWriter(str(tmp_path), "w0")
    w.beat(round=3)
    mon = HeartbeatMonitor(str(tmp_path), stale_s=5.0)
    assert "w0" in mon.alive()
    assert mon.alive()["w0"]["round"] == 3
    assert mon.dead(["w0", "w1"]) == ["w1"]
    stale = HeartbeatMonitor(str(tmp_path), stale_s=0.0)
    time.sleep(0.01)
    assert "w0" not in stale.alive()


def test_with_retries():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ConnectionError("transient")
        return "ok"

    assert with_retries(flaky, RetryPolicy(max_attempts=5,
                                           base_delay_s=0.01))() == "ok"
    assert len(calls) == 3


def test_overprovision_math():
    assert overprovision(4, 0.0) == 4
    n = overprovision(4, 0.3, confidence=0.99)
    assert n > 4
    # sanity: with n workers at p=0.3 failure, P[>=4 ok] >= 0.99
    import math

    p_ok = sum(math.comb(n, k) * 0.7 ** k * 0.3 ** (n - k)
               for k in range(4, n + 1))
    assert p_ok >= 0.99
