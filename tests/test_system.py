"""End-to-end behaviour of the paper's system inside the framework.

One test = one complete story: data produced at a "site", routed by policy,
consumed by a payload-capped FaaS task via a transparent proxy, model state
checkpointed as a manifest of proxies, restored lazily, and served.
"""
import os
import pickle

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import (MultiConnector, Policy, Store, get_factory,
                        is_resolved)
from repro.core.connectors import FileConnector, LocalMemoryConnector
from repro.core.store import unregister_store


def test_end_to_end_proxy_lifecycle(tmp_path):
    """Paper Listing 1 + §4.3 + §3.5 in one flow."""
    multi = MultiConnector([
        (LocalMemoryConnector(), Policy(max_size=10_000, priority=10,
                                        tags=frozenset({"local"}))),
        (FileConnector(str(tmp_path / "bulk")),
         Policy(priority=0, tags=frozenset({"local", "persistent"}))),
    ])
    store = Store("system-store", multi)

    # producer: big array routes to the persistent channel by size policy
    data = np.random.default_rng(0).standard_normal(100_000).astype(np.float32)
    proxy = store.proxy(data, evict=True)
    wire = pickle.dumps(proxy)
    assert len(wire) < 2000

    # "remote" consumer: fresh registry, resolves just-in-time and drops
    # its reference — the producer's sibling still holds one, so the key
    # survives (the old fire-and-forget evict would have broken it here)
    unregister_store("system-store")
    p2 = pickle.loads(wire)
    assert not is_resolved(p2)
    assert float(np.sum(p2)) == pytest.approx(float(np.sum(data)), rel=1e-6)
    key = get_factory(p2).key
    from repro.core import get_store

    assert get_store("system-store").exists(key)  # producer's ref remains
    # the producer consumes its sibling too: LAST reference drops -> evicted
    assert float(np.sum(proxy)) == pytest.approx(float(np.sum(data)),
                                                 rel=1e-6)
    assert not get_store("system-store").exists(key)  # refcount hit zero


@pytest.mark.slow
def test_end_to_end_train_checkpoint_serve(tmp_path):
    """Train a tiny arch -> proxy-checkpoint -> lazy-restore -> serve."""
    import jax

    from repro.core.connectors import SharedMemoryConnector
    from repro.serve.engine import Request, ServeEngine
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainConfig, Trainer

    cfg = ARCHS["qwen2.5-14b"].reduced().replace(
        n_layers=2, d_model=64, d_ff=128, vocab=128)
    tc = TrainConfig(steps=8, batch=2, seq=32, ckpt_every=4, log_every=4,
                     workdir=str(tmp_path / "run"))
    tr = Trainer(cfg, tc, OptConfig(peak_lr=1e-3, warmup_steps=2,
                                    decay_steps=8))
    res = tr.run()
    assert res["final_loss"] is not None
    assert tr.ckpts.latest_step() == 8

    # serving engine restores weights from the manifest of proxies
    engine = ServeEngine(cfg, ckpts=tr.ckpts, max_batch=2)
    out = engine.generate([Request(prompt=[1, 2, 3], max_new_tokens=4)])
    assert len(out["outputs"][0]) == 4
    assert all(0 <= t < cfg.vocab for t in out["outputs"][0])
