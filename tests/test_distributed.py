"""Distribution layer: sharding rules (host-side) + multi-device subprocess
tests (8 fake devices; the main pytest process keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_on_fake_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# host-side rule resolution (no devices needed)
# ---------------------------------------------------------------------------
def test_resolve_spec_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import resolve_spec
    from repro.launch.mesh import make_mesh

    # 1 real device is fine: mesh shape (1,1) won't exercise divisibility;
    # use abstract mesh math via a fake mesh of size 1 but rule table sizes
    # come from mesh.shape -> use subprocess for real 16x16; here just the
    # degenerate no-op case
    mesh = make_mesh((1,), ("model",))
    spec = resolve_spec(mesh, (8, 16), ("kv_heads", None),
                        {"kv_heads": "model"})
    assert spec == P("model", None) or spec == P(None, None)


def test_rules_tables_cover_all_arch_params():
    """Every parameter leaf of every arch resolves to a spec (subprocess
    with a 16x16-like mesh via 8 devices 4x2)."""
    out = run_on_fake_devices("""
        import jax
        from repro.configs import ARCHS
        from repro.distributed.rules import make_rules, tree_specs
        from repro.launch.mesh import make_mesh
        from repro.models.model import abstract_params

        mesh = make_mesh((4, 2), ("data", "model"))
        rules = make_rules(mesh)
        for name, cfg in ARCHS.items():
            params = abstract_params(cfg.reduced())
            specs = tree_specs(mesh, rules, params)
            assert jax.tree.structure(specs) == jax.tree.structure(params)
        print("OK")
    """)
    assert "OK" in out


def test_dp_tp_loss_matches_single_device():
    """The sharded train loss equals the unsharded loss bit-for-bit-ish."""
    out = run_on_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import ARCHS
        from repro.distributed.rules import (batch_specs_tree, make_rules,
                                             tree_specs)
        from repro.distributed.sharding import sharding_rules
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model

        cfg = ARCHS["qwen2.5-14b"].reduced().replace(
            dtype="float32", n_heads=4, n_kv_heads=2, head_dim=32)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 64),
                                              0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.key(2), (4, 64),
                                              0, cfg.vocab)}
        ref, _ = model.loss(params, batch)          # single device

        mesh = make_mesh((4, 2), ("data", "model"))
        rules = make_rules(mesh)
        with sharding_rules(mesh, rules):
            ns = lambda s: NamedSharding(mesh, s)
            p_sh = jax.tree.map(ns, tree_specs(mesh, rules, params))
            b_sh = jax.tree.map(ns, batch_specs_tree(mesh, rules, batch))
            f = jax.jit(lambda p, b: model.loss(p, b)[0],
                        in_shardings=(p_sh, b_sh))
            sharded = f(params, batch)
        np.testing.assert_allclose(float(ref), float(sharded), rtol=2e-5)
        print("LOSS", float(ref), float(sharded))
    """)
    assert "LOSS" in out


def test_pipeline_parallel_selftest():
    out = run_on_fake_devices(
        "import repro.distributed.pipeline_parallel as pp; pp._selftest()")
    assert "selftest OK" in out


def test_dryrun_single_cell_on_tiny_mesh():
    """The full dry-run path (lower+compile+census) works end-to-end on a
    reduced arch over a small mesh."""
    out = run_on_fake_devices("""
        import jax
        from repro.configs import ARCHS, SHAPES
        from repro.configs.base import ShapeConfig
        from repro.launch.dryrun import lower_cell, collective_census
        from repro.launch.mesh import make_mesh

        cfg = ARCHS["phi4-mini-3.8b"].reduced()
        shape = ShapeConfig("t", "train", 64, 8)
        mesh = make_mesh((4, 2), ("data", "model"))
        compiled, secs = lower_cell(cfg, shape, mesh)
        ma = compiled.memory_analysis()
        census = collective_census(compiled.as_text())
        assert ma.temp_size_in_bytes > 0
        assert any(census.values()), census
        print("CELL OK", sum(c["count"] for c in census.values()))
    """)
    assert "CELL OK" in out


def test_multipod_mesh_axes():
    out = run_on_fake_devices("""
        from repro.launch.mesh import make_production_mesh
        # 512 fake devices needed for the real mesh; with 8 we just check
        # the factory validates its own shape logic via make_mesh
        from repro.launch.mesh import make_mesh
        m = make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert m.axis_names == ("pod", "data", "model")
        print("MESH OK")
    """)
    assert "MESH OK" in out
