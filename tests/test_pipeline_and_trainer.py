"""Proxy data pipeline + restartable trainer (fault-tolerance contract)."""
import os
from functools import partial

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import Store
from repro.core.connectors import SharedMemoryConnector
from repro.core.store import unregister_store
from repro.data.datasets import lm_batch
from repro.data.pipeline import ProxyDataPipeline
from repro.train.trainer import TrainConfig, Trainer

TINY = ARCHS["phi4-mini-3.8b"].reduced().replace(
    n_layers=2, d_model=64, d_ff=128, vocab=128)


def test_dataset_determinism():
    a = lm_batch(7, 3, 4, 32, 100)
    b = lm_batch(7, 3, 4, 32, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(7, 4, 4, 32, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_pipeline_order_and_determinism(tmp_path):
    store = Store("pipe-a", SharedMemoryConnector(str(tmp_path / "shm")))
    make = partial(lm_batch, 42, batch=2, seq=16, vocab=50)
    pipe = ProxyDataPipeline(store, make, n_producers=2, deadline_s=20)
    try:
        batches = [next(pipe) for _ in range(6)]
        for i, b in enumerate(batches):
            np.testing.assert_array_equal(b["tokens"], make(i)["tokens"])
    finally:
        pipe.close()


def test_pipeline_redundancy_survives_producer_death(tmp_path):
    """The real straggler guarantee: kill the primary producer mid-stream;
    the redundant rank keeps the (deterministic) stream flowing without
    inline fallbacks.  (With all producers healthy, queue backpressure
    keeps duplicate production near zero — bounded waste by design.)"""
    store = Store("pipe-b", SharedMemoryConnector(str(tmp_path / "shm")))
    make = partial(lm_batch, 1, batch=2, seq=16, vocab=50)
    pipe = ProxyDataPipeline(store, make, n_producers=1, redundancy=2,
                             deadline_s=30)
    try:
        for i in range(3):
            b = next(pipe)
            np.testing.assert_array_equal(b["tokens"], make(i)["tokens"])
        pipe._procs[0].terminate()          # primary producer dies
        pipe._procs[0].join(timeout=5)
        for i in range(3, 8):               # redundant rank takes over
            b = next(pipe)
            np.testing.assert_array_equal(b["tokens"], make(i)["tokens"])
        assert pipe.stats["fallbacks"] == 0
    finally:
        pipe.close()


def test_pipeline_straggler_fallback(tmp_path):
    store = Store("pipe-c", SharedMemoryConnector(str(tmp_path / "shm")))
    make = partial(lm_batch, 2, batch=2, seq=16, vocab=50)
    pipe = ProxyDataPipeline(store, make, n_producers=1, deadline_s=0.05,
                             straggler_delay_s=30.0)
    try:
        b = next(pipe)  # producer sleeping -> inline fallback
        np.testing.assert_array_equal(b["tokens"], make(0)["tokens"])
        assert pipe.stats["fallbacks"] == 1
    finally:
        pipe.close()


@pytest.mark.slow
def test_trainer_learns_and_resumes(tmp_path):
    from repro.train.optimizer import OptConfig

    opt = OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=20)
    tc = TrainConfig(steps=20, batch=4, seq=32, log_every=5, ckpt_every=5,
                     workdir=str(tmp_path / "runA"))
    tr = Trainer(TINY, tc, opt)
    res = tr.run()
    assert res["final_loss"] < tr.history[0]["loss"]
    unregister_store(tr.store.name)

    # crash at step 12, resume, and verify the resumed stream CONTINUES
    tc2 = TrainConfig(steps=20, batch=4, seq=32, log_every=5, ckpt_every=5,
                      workdir=str(tmp_path / "runB"), crash_at_step=12)
    tr2 = Trainer(TINY, tc2)
    with pytest.raises(RuntimeError, match="injected crash"):
        tr2.run()
    unregister_store(tr2.store.name)
    assert tr2.ckpts.latest_step() == 10

    tc3 = TrainConfig(steps=20, batch=4, seq=32, log_every=5, ckpt_every=5,
                      workdir=str(tmp_path / "runB"), resume=True)
    tr3 = Trainer(TINY, tc3)
    res3 = tr3.run()
    # bitwise continuity: same data stream + state -> same final metrics
    # as an uninterrupted run with the same seed
    uninterrupted = Trainer(
        TINY, TrainConfig(steps=20, batch=4, seq=32, log_every=5,
                          ckpt_every=50, workdir=str(tmp_path / "runC")))
    res_c = uninterrupted.run()
    assert abs(res3["final_loss"] - res_c["final_loss"]) < 5e-3
