"""The pipelined multiplexed KV transport: seq-tagged futures, concurrent
in-flight requests, out-of-order completion, batch ops, reconnect semantics."""
import os
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import deserialize, serialize
from repro.core.kv_tcp import KVClient, spawn_server


@pytest.fixture()
def kv(tmp_path):
    host, port, pid = spawn_server(ready_file=str(tmp_path / "kv.ready"))
    client = KVClient(host, port)
    yield client
    client.shutdown_server()
    client.close()
    try:
        os.kill(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        pass


def test_concurrent_threads_one_client(kv):
    """Many threads share ONE client/connection with requests in flight."""
    n_threads, n_ops = 8, 25
    errors: list[Exception] = []

    def worker(tid: int) -> None:
        try:
            for i in range(n_ops):
                key = f"t{tid}-{i}"
                val = (f"{tid}:{i}".encode()) * (i + 1)
                kv.put(key, val)
                assert bytes(kv.get(key)) == val
                assert kv.exists(key)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # one connection served everything
    assert kv.n_reconnects == 1


def test_pipelined_futures_and_out_of_order_completion(kv):
    """A slow op must not block later ops on the same connection."""
    slow = kv.submit({"op": "sleep", "s": 1.0})
    t0 = time.perf_counter()
    fast = kv.submit({"op": "ping"})
    assert fast.result(5)["data"] == "pong"
    assert time.perf_counter() - t0 < 0.5      # overtook the sleeping op
    assert not slow.done()                     # still parked server-side
    assert slow.result(5)["ok"]


def test_many_in_flight_one_round_trip(kv):
    """N pipelined puts then N pipelined gets, all submitted before any
    wait — the futures all complete without per-op round trips."""
    puts = [kv.put_async(f"k{i}", b"v%d" % i) for i in range(64)]
    for f in puts:
        f.result(10)
    gets = [kv.get_async(f"k{i}") for i in range(64)]
    assert [bytes(f.result(10)) for f in gets] == \
        [b"v%d" % i for i in range(64)]


def test_mput2_mget2_roundtrip(kv):
    keys = [f"m{i}" for i in range(10)]
    blobs = [os.urandom(i * 100) for i in range(10)]   # includes empty
    kv.mput(keys, blobs)
    got = kv.mget(keys + ["missing"])
    assert [None if g is None else bytes(g) for g in got] == blobs + [None]
    assert kv.mget([]) == []


def test_mput2_streams_frames_zero_copy(kv):
    """PSJ2 Frames go through mput2 as raw segments and come back intact."""
    arrays = [np.random.default_rng(i).standard_normal(2000) for i in range(4)]
    kv.mput([f"f{i}" for i in range(4)], [serialize(a) for a in arrays])
    for i, blob in enumerate(kv.mget([f"f{i}" for i in range(4)])):
        np.testing.assert_array_equal(deserialize(blob), arrays[i])


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def test_reconnect_with_pending_futures(tmp_path):
    """Server death fails every pending future with ConnectionError; the
    next request transparently reconnects once a server is back."""
    port = _free_port()
    host, port, pid = spawn_server(port=port,
                                   ready_file=str(tmp_path / "kv1.ready"))
    client = KVClient(host, port)
    client.put("persists-not", b"x")
    pending = [client.submit({"op": "sleep", "s": 30}) for _ in range(3)]
    assert not any(f.done() for f in pending)
    os.kill(pid, signal.SIGKILL)
    for fut in pending:
        with pytest.raises(ConnectionError):
            fut.result(10)
    # server comes back on the same address: client reconnects on demand
    spawn_server(port=port, ready_file=str(tmp_path / "kv2.ready"))
    assert client.ping()
    client.put("after", b"reborn")
    assert bytes(client.get("after")) == b"reborn"
    assert client.n_reconnects >= 2
    client.shutdown_server()
    client.close()


def test_closed_client_raises(kv):
    kv.put("a", b"1")
    kv.close()
    with pytest.raises(ConnectionError):
        kv.get("a")
    # fixture teardown shutdown_server tolerates the closed client
    kv.shutdown_server()


def test_persisted_put_then_get_ordering(tmp_path):
    """Regression (review): with --persist-dir, put2 runs on a task while
    reads can take the server's inline fast path — a pipelined get right
    behind a put_async must still observe the put (submission order on
    one connection), not answer from the read callback before the put's
    memory write lands."""
    host, port, _pid = spawn_server(ready_file=str(tmp_path / "kv.ready"),
                                    persist_dir=str(tmp_path / "pd"))
    c = KVClient(host, port)
    misses = 0
    for i in range(50):
        c.put_async(f"k{i}", f"v{i}".encode())
        got = c.get(f"k{i}")                 # pipelined right behind
        if got is None or bytes(got) != f"v{i}".encode():
            misses += 1
    assert misses == 0
    c.shutdown_server()
    c.close()


def test_persistence_off_loop_does_not_stall_peers(tmp_path):
    """With --persist-dir, a client streaming persisting puts must not
    serialize a second client's reads behind its disk writes."""
    host, port, _pid = spawn_server(ready_file=str(tmp_path / "kv.ready"),
                                    persist_dir=str(tmp_path / "pd"))
    writer = KVClient(host, port)
    reader = KVClient(host, port)
    writer.put("warm", b"w")
    blob = os.urandom(200_000)
    futs = [writer.put_async(f"big{i}", blob) for i in range(20)]
    t0 = time.perf_counter()
    assert reader.exists("warm")
    read_latency = time.perf_counter() - t0
    for f in futs:
        f.result(30)
    assert read_latency < 1.0
    # write-through survived: respawn from the same dir
    writer.shutdown_server()
    h2, p2, _ = spawn_server(ready_file=str(tmp_path / "kv2.ready"),
                             persist_dir=str(tmp_path / "pd"))
    c2 = KVClient(h2, p2)
    assert bytes(c2.get("big7")) == blob
    c2.shutdown_server()
    for c in (writer, reader, c2):
        c.close()
