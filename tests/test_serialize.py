"""Serializer round-trip correctness (incl. hypothesis pytrees)."""
import numpy as np
import pytest

try:  # optional: property tests only run when hypothesis is installed
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import deserialize, serialize


def rt(obj):
    return deserialize(serialize(obj))


def test_scalars_and_containers():
    obj = {"a": 1, "b": 2.5, "c": "x", "d": None, "e": True,
           "f": b"bytes", "g": [1, [2, 3]], "h": (4, (5,)), "i": {7, 8}}
    out = rt(obj)
    assert out == obj
    assert isinstance(out["h"], tuple) and isinstance(out["h"][1], tuple)
    assert isinstance(out["i"], set)


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "int64",
                                   "uint8", "bool"])
def test_numpy_dtypes(dtype):
    arr = np.arange(24).reshape(2, 3, 4).astype(dtype)
    out = rt(arr)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)
    assert out.flags.writeable


def test_bfloat16_and_jax_arrays():
    import jax.numpy as jnp
    import ml_dtypes

    x = jnp.linspace(0, 1, 16, dtype=jnp.bfloat16).reshape(4, 4)
    out = rt({"w": x})["w"]
    assert str(out.dtype) == "bfloat16"
    np.testing.assert_array_equal(out.astype(np.float32),
                                  np.asarray(x).astype(np.float32))
    f8 = np.zeros((3,), ml_dtypes.float8_e4m3fn)
    assert str(rt(f8).dtype) == "float8_e4m3fn"


def test_frame_magic_and_roundtrip_via_wire():
    """serialize -> Frame; its contiguous wire image is a PSJ2 frame."""
    from repro.core import Frame, frame_nbytes

    f = serialize({"x": b"x" * 100})
    assert isinstance(f, Frame)
    wire = bytes(f)
    assert wire[:4] == b"PSJ2"
    assert len(wire) == f.nbytes == frame_nbytes(f)
    assert deserialize(wire) == {"x": b"x" * 100}


def test_empty_and_zero_dim():
    np.testing.assert_array_equal(rt(np.zeros((0, 3))), np.zeros((0, 3)))
    out = rt(np.float32(3.5))
    assert float(out) == 3.5


def _boom():
    raise RuntimeError("resolved!")


def test_proxies_never_resolved_by_serializer():
    from functools import partial

    from repro.core import Proxy, is_resolved

    boom = Proxy(_boom)
    serialize({"p": boom})  # must NOT resolve (array duck-typing guard)
    assert not is_resolved(boom)
    p = Proxy(partial(int, 7))
    assert deserialize(serialize(p)) == 7


if HAVE_HYPOTHESIS:
    _leaf = st.one_of(
        st.integers(min_value=-2**31, max_value=2**31 - 1),
        st.floats(allow_nan=False, allow_infinity=False, width=32),
        st.text(max_size=16),
        st.booleans(),
        hnp.arrays(dtype=st.sampled_from([np.float32, np.int32, np.uint8]),
                   shape=hnp.array_shapes(max_dims=3, max_side=5)),
    )
    _tree = st.recursive(
        _leaf,
        lambda children: st.one_of(
            st.lists(children, max_size=4),
            st.dictionaries(st.text(max_size=6), children, max_size=4),
            st.tuples(children, children),
        ),
        max_leaves=12)

    @settings(max_examples=40, deadline=None)
    @given(_tree)
    def test_property_pytree_roundtrip(tree):
        out = rt(tree)

        def eq(a, b):
            if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
                np.testing.assert_array_equal(a, b)
                assert np.asarray(a).dtype == np.asarray(b).dtype
                return
            assert type(a) is type(b)
            if isinstance(a, dict):
                assert a.keys() == b.keys()
                for k in a:
                    eq(a[k], b[k])
            elif isinstance(a, (list, tuple)):
                assert len(a) == len(b)
                for x, y in zip(a, b):
                    eq(x, y)
            else:
                assert a == b

        eq(tree, out)
