import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
# device; only launch/dryrun.py (a separate process) forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so the benchmark smoke tests can import the benchmarks package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest


@pytest.fixture(autouse=True)
def _clean_store_registry():
    """Each test gets a fresh global Store registry + lifecycle tables."""
    yield
    from repro.core import connector as conn_mod
    from repro.core import store as store_mod

    with store_mod._REGISTRY_LOCK:
        store_mod._REGISTRY.clear()
    with conn_mod._LIFETIME_LOCK:
        conn_mod._LIFETIME_TABLES.clear()
