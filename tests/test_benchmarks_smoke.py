"""Smoke-run the paper-figure benchmarks at tiny sizes.

The Fig 5/6 scripts exercise the serializer + every in-memory connector end
to end; running them here means serializer/connector API drift breaks tier-1
loudly instead of silently rotting the paper figures.
"""
import benchmarks.fig5_faas_rtt as fig5
import benchmarks.fig6_inmemory as fig6
import benchmarks.fig12_ownership as fig12
import benchmarks.fig13_futures as fig13
from benchmarks.util import time_call


def _fast_time_call(fn, **_kw):
    return time_call(fn, reps=1, warmup=0)


def test_fig6_smoke(monkeypatch):
    monkeypatch.setattr(fig6, "SIZES", [10_000])
    monkeypatch.setattr(fig6, "time_call", _fast_time_call)
    fig6.run()


def test_fig5_smoke(monkeypatch):
    monkeypatch.setattr(fig5, "SIZES", [10_000])
    monkeypatch.setattr(fig5, "time_call", _fast_time_call)
    fig5.run()


def test_fig12_smoke(monkeypatch):
    monkeypatch.setattr(fig12, "SIZE", 10_000)
    monkeypatch.setattr(fig12, "FANOUTS", [3])
    monkeypatch.setattr(fig12, "time_call", _fast_time_call)
    fig12.run()


def test_fig13_smoke(monkeypatch):
    monkeypatch.setattr(fig13, "N_CHUNKS", 4)
    monkeypatch.setattr(fig13, "CHUNK_BYTES", 10_000)
    monkeypatch.setattr(fig13, "T_PRODUCE", 0.01)
    monkeypatch.setattr(fig13, "T_CONSUME", 0.01)
    fig13.run()   # asserts producer/consumer overlap beats the baseline
