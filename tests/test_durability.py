"""Durability tier: server-side chain replication, hinted handoff,
replica repair, replicated consumer-group cursors, and dead-letter
queues.

The ``chaos``-marked tests SIGKILL shards mid-workload — they run in the
nightly tier alongside ``slow``; everything else runs in tier-1.
"""
from __future__ import annotations

import time

import pytest

from repro.core.deploy import start_kvserver
from repro.core.fabric import ShardedConnector
from repro.core.kv_tcp import KVClient, dlq_topic, stream_item_key
from repro.core.connectors.memory import LocalMemoryConnector
from repro.distributed.chaos import (FaultSchedule, Partition,
                                     crash_during_cursor_replication,
                                     kill_shard)
from repro.distributed.fault_tolerance import RetryPolicy, with_retries
from repro.stream.interface import StreamConsumer
from repro.stream.local import LocalBroker


@pytest.fixture
def cluster(tmp_path):
    """Four UDS shards + a replication-2 quorum connector (chain
    replication on by default)."""
    handles = [start_kvserver(str(tmp_path), name=f"s{i}", uds=True)
               for i in range(4)]
    fab = ShardedConnector([h.host for h in handles], replication=2,
                           quorum=True, op_timeout=5.0)
    yield handles, fab
    fab.close()
    for h in handles:
        h.stop()


def _handle_for(sid: str, handles):
    return next(h for h in handles if h.host == sid)


# ---------------------------------------------------------------------------
# retry policy + chaos primitives (no servers)
# ---------------------------------------------------------------------------
def test_retry_policy_backoff_jitter_and_deadline():
    pol = RetryPolicy(max_attempts=5, base_delay_s=0.1, max_delay_s=0.4,
                      jitter=0.5)
    for attempt, lo in ((0, 0.1), (1, 0.2), (2, 0.4), (5, 0.4)):
        d = pol.delay_for(attempt)
        assert lo <= d <= lo * 1.5          # exponential, capped, jittered
    start = time.monotonic()
    capped = RetryPolicy(deadline_s=0.05)
    assert not capped.expired(start)
    assert capped.expired(start, next_delay=1.0)   # sleep would overrun
    assert not RetryPolicy(deadline_s=None).expired(start, 1e9)


def test_with_retries_respects_total_deadline():
    calls: list[int] = []

    def boom():
        calls.append(1)
        raise ConnectionError("injected")

    # deadline 0: any backoff overruns it — one attempt, no sleep
    pol = RetryPolicy(max_attempts=5, base_delay_s=0.05, deadline_s=0.0)
    with pytest.raises(ConnectionError):
        with_retries(boom, pol)()
    assert len(calls) == 1
    calls.clear()
    # no deadline: the full attempt budget is spent
    pol = RetryPolicy(max_attempts=3, base_delay_s=0.001, deadline_s=None)
    with pytest.raises(ConnectionError):
        with_retries(boom, pol)()
    assert len(calls) == 3


def test_fault_schedule_fires_in_order_and_records_errors():
    out: list[str] = []

    def bad():
        raise ValueError("injected")

    sched = FaultSchedule([(0.01, lambda: out.append("a"), "one"),
                           (0.01, bad, "two")]).start()
    sched.join(5.0)
    assert sched.fired == ["one", "two"] and out == ["a"]
    assert [(lbl, type(e)) for lbl, e in sched.errors] \
        == [("two", ValueError)]
    # cancel stops unfired steps
    sched = FaultSchedule([(0.5, lambda: out.append("late"), "late")])
    sched.start()
    sched.cancel()
    sched.join(5.0)
    assert sched.fired == [] and "late" not in out


def test_partition_blackholes_every_link_symmetrically():
    class _Link:
        def __init__(self):
            self.black = None

        def blackhole(self, on=True):
            self.black = bool(on)

    a, b = _Link(), _Link()
    with Partition(a, b) as cut:
        assert cut.active and a.black and b.black
    assert not cut.active and a.black is False and b.black is False


# ---------------------------------------------------------------------------
# chain replication: one upload, server-side forwarding
# ---------------------------------------------------------------------------
def test_chain_put_single_upload_and_replica_presence(cluster):
    handles, fab = cluster
    legacy = ShardedConnector([h.host for h in handles], replication=2,
                              quorum=True, op_timeout=5.0, chain=False)
    try:
        blobs = [bytes([i % 256]) * 8192 for i in range(24)]
        keys = fab.put_batch(blobs)
        legacy.put_batch(blobs)
        # both modes leave every key on `replication` distinct shards
        clients = [KVClient(h.host, h.port) for h in handles]
        for key in keys:
            assert sum(c.exists(key[1]) for c in clients) == fab.replication
        for c in clients:
            c.close()
        # ...but the chain path uploads ONE copy: the client egress is
        # about 1/R of the legacy R-copy fanout (plus protocol overhead)
        chain_tx = fab.stats()["fabric"]["client_tx_bytes"]
        legacy_tx = legacy.stats()["fabric"]["client_tx_bytes"]
        assert chain_tx < 0.75 * legacy_tx
        st = fab.stats()["fabric"]
        assert st["chain"] and st["n_repl_errors"] == 0
        assert st["n_repairs_pending"] == 0
    finally:
        legacy.close()


def test_hinted_handoff_replays_on_recovery(cluster):
    handles, fab = cluster
    oid = "hinted-object"
    blob = b"hinted-payload" * 64
    owners = fab._owners(oid)
    primary, successor = owners[0], owners[1]
    fab._suspect(primary)
    fab._put_object(oid, blob)
    ca = KVClient(*(_handle_for(primary, handles).host, 0))
    cb = KVClient(*(_handle_for(successor, handles).host, 0))
    try:
        # the put landed on the successor with a hint record instead of
        # being forwarded to the suspect primary
        assert not ca.exists(oid) and cb.exists(oid)
        assert primary in cb.hints()
        assert fab.stats()["fabric"]["n_hint_shards_pending"] >= 1
        # first successful exchange with the primary replays the hint
        fab._mark_ok(primary)
        assert bytes(ca.get(oid)) == blob
        assert not cb.hints().get(primary)
        st = fab.stats()["fabric"]
        assert st["n_hints_replayed"] >= 1
        assert st["n_hint_shards_pending"] == 0
    finally:
        ca.close()
        cb.close()


@pytest.mark.chaos
def test_replica_write_failure_surfaces_and_repairs(cluster, tmp_path):
    """Satellite regression: kill a chain successor mid-put-storm — the
    head's per-hop errors surface in stats and queue repairs; when the
    shard answers again every owed replica copy is re-put."""
    handles, fab = cluster
    dead_id = handles[3].host
    kill_shard(handles[3])
    for i in range(200):
        fab.put(f"payload-{i}".encode() * 32)
        st = fab.stats()["fabric"]
        if st["n_repl_errors"] and st["n_repairs_pending"]:
            break
    st = fab.stats()["fabric"]
    assert st["n_repl_errors"] > 0 and st["n_repairs_pending"] > 0
    owed = [oid for (sid, oid) in fab._repair_q if sid == dead_id]
    assert owed
    # revive the shard on the same socket; recovery rides ordinary
    # traffic via the _mark_ok hook
    handles[3] = start_kvserver(str(tmp_path), name="s3", uds=True)
    deadline = time.monotonic() + 30.0
    while fab.stats()["fabric"]["n_repairs_pending"]:
        fab._mark_ok(dead_id)
        if time.monotonic() > deadline:
            break
        time.sleep(0.05)
    st = fab.stats()["fabric"]
    assert st["n_repairs_pending"] == 0 and st["n_repaired"] > 0
    revived = KVClient(handles[3].host, handles[3].port)
    try:
        for oid in owed:
            assert revived.exists(oid)
    finally:
        revived.close()


# ---------------------------------------------------------------------------
# replicated group cursors: snapshot / restore / chain push
# ---------------------------------------------------------------------------
def test_stream_snapshot_restore_roundtrip(tmp_path):
    h0 = start_kvserver(str(tmp_path), name="a", uds=True)
    h1 = start_kvserver(str(tmp_path), name="b", uds=True)
    c0 = KVClient(h0.host, h0.port)
    c1 = KVClient(h1.host, h1.port)
    try:
        c0.stream_sub("t", "g")
        c0.stream_limit("t", 5, max_deliveries=3)
        for i in range(3):
            c0.stream_append("t", f"e{i}".encode())
        ev = c0.stream_take("t", "g", timeout=5.0)
        assert ev["seq"] == 0                    # delivered, left unacked
        snap = c0.stream_snap("t")
        assert snap["count"] == 3 and not snap["closed"]
        assert sorted(snap["groups"]["g"]["queue"]) == [1, 2]
        assert list(snap["groups"]["g"]["unacked"]) == [0]
        assert ["g", 0, 1] in [list(d) for d in snap["deliveries"]]
        # payload bytes travel separately — copy the owned item keys
        for s in snap["owners"]:
            key = stream_item_key("t", int(s))
            c1.put(key, bytes(c0.get(key)))
        c1.stream_restore("t", snap)
        stat = c1.stream_stat("t")
        assert stat["count"] == 3 and stat["max_deliveries"] == 3
        assert stat["groups"]["g"] == {"queued": 2, "unacked": 1}
        ev = c1.stream_take("t", "g", timeout=5.0)
        assert ev["seq"] == 1 and bytes(ev["data"]) == b"e1"
        # drop forgets the topic and evicts its payload keys
        c1.stream_drop("t")
        assert c1.stream_stat("t")["count"] == 0
        assert not c1.exists(stream_item_key("t", 2))
    finally:
        c0.close()
        c1.close()
        h0.stop()
        h1.stop()


def test_stream_chain_pushes_cursor_to_replica(tmp_path):
    h0 = start_kvserver(str(tmp_path), name="a", uds=True)
    h1 = start_kvserver(str(tmp_path), name="b", uds=True)
    c0 = KVClient(h0.host, h0.port)
    c1 = KVClient(h1.host, h1.port)
    try:
        c0.stream_chain("t", [h1.host])
        c0.stream_sub("t", "g")
        for i in range(2):
            c0.stream_append("t", f"e{i}".encode())
        # chained appends commit synchronously on every chain member:
        # payload AND cursor are on the replica before the append acks
        snap = c1.stream_snap("t")
        assert snap["count"] == 2
        assert bytes(c1.get(stream_item_key("t", 0))) == b"e0"
        # group-state mutations push asynchronously (coalesced)
        ev = c0.stream_take("t", "g", timeout=5.0)
        c0.stream_ack("t", "g", [ev["seq"]])
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            g = c1.stream_snap("t")["groups"]["g"]
            if not g["unacked"] and 0 not in g["queue"]:
                break
            time.sleep(0.05)
        g = c1.stream_snap("t")["groups"]["g"]
        assert not g["unacked"] and list(g["queue"]) == [1]
    finally:
        c0.close()
        c1.close()
        h0.stop()
        h1.stop()


# ---------------------------------------------------------------------------
# dead-letter queues
# ---------------------------------------------------------------------------
def test_dlq_local_broker_moves_poison_event():
    b = LocalBroker()
    b.subscribe("t", "g")
    b.set_limit("t", None, max_deliveries=2)
    b.publish("t", b"poison", meta={"job": 7})
    for expect_back in (True, False):
        ev = b.take("t", "g", timeout=5.0)
        assert ev.seq == 0 and bytes(ev.data) == b"poison"
        b.requeue("t", "g", [ev.seq], reason="handler crashed")
        assert bool(b.stat("t")["groups"]["g"]["queued"]) == expect_back
    # second requeue hit max_deliveries: the event moved to <topic>.dlq
    st = b.stat("t")["groups"]["g"]
    assert st == {"queued": 0, "unacked": 0}
    b.subscribe(dlq_topic("t"), "aud", start="begin")
    dev = b.take(dlq_topic("t"), "aud", timeout=5.0)
    assert bytes(dev.data) == b"poison" and dev.meta["job"] == 7
    assert dev.meta["dlq"] == {"topic": "t", "group": "g", "seq": 0,
                               "deliveries": 2,
                               "reason": "handler crashed"}


def test_dlq_fallback_connector_moves_poison_event():
    conn = LocalMemoryConnector()
    try:
        conn.stream_subscribe("t", "g")
        conn.stream_subscribe(dlq_topic("t"), "aud")
        conn.stream_limit("t", None, max_deliveries=1)
        conn.stream_append("t", b"poison", meta={"job": 1})
        ev = conn.stream_take("t", "g", timeout=5.0)
        assert conn.stream_requeue("t", "g", [ev.seq], reason="boom") == 0
        dev = conn.stream_take(dlq_topic("t"), "aud", timeout=5.0)
        assert bytes(dev.data) == b"poison"
        assert dev.meta["dlq"] == {"topic": "t", "group": "g", "seq": 0,
                                   "deliveries": 1, "reason": "boom"}
        st = conn.stream_stat("t")["groups"]["g"]
        assert st == {"queued": 0, "unacked": 0}
    finally:
        conn.close()


def test_consumer_dedup_acks_and_skips_redelivered():
    b = LocalBroker()
    c = StreamConsumer(b, "t", "g", prefetch=0, dedup=True, ack_every=100,
                       timeout=5.0)
    for i in range(3):
        b.publish("t", f"e{i}".encode())
    b.close_topic("t")
    assert next(c) == b"e0"
    # failover-style redelivery: hand the delivered-but-unacked event
    # back to the group — the dedup consumer must not yield it twice
    b.requeue("t", "g", [0])
    assert list(c) == [b"e1", b"e2"]
    c.close()
    # the duplicate was acked (its reference released), not leaked
    assert b.stat("t")["groups"]["g"] == {"queued": 0, "unacked": 0}


# ---------------------------------------------------------------------------
# rebalance with active consumer groups (cursors + DLQ travel)
# ---------------------------------------------------------------------------
def test_rebalance_preserves_cursors_and_dlq(cluster, tmp_path):
    handles, fab = cluster
    fab.stream_subscribe("jobs", "g")
    fab.stream_subscribe(dlq_topic("jobs"), "aud")
    fab.stream_limit("jobs", None, max_deliveries=1)
    for i in range(6):
        fab.stream_append("jobs", f"j{i}".encode(), meta={"i": i})
    ev = fab.stream_take("jobs", "g", timeout=5.0)
    fab.stream_ack("jobs", "g", [ev.seq])              # j0 done
    ev = fab.stream_take("jobs", "g", timeout=5.0)
    assert ev.seq == 1
    fab.stream_requeue("jobs", "g", [ev.seq], reason="poison")  # -> DLQ
    owners_before = fab._owners("@t:jobs")
    extra = start_kvserver(str(tmp_path), name="s4", uds=True)
    try:
        fab.add_shard(extra.host)
        for seq in (2, 3):                  # cursor survived the move
            ev = fab.stream_take("jobs", "g", timeout=5.0)
            assert ev.seq == seq and bytes(ev.data) == f"j{seq}".encode()
            fab.stream_ack("jobs", "g", [ev.seq])
        # removing the old primary forces the topic home to move again
        fab.remove_shard(owners_before[0])
        for seq in (4, 5):
            ev = fab.stream_take("jobs", "g", timeout=5.0)
            assert ev.seq == seq and bytes(ev.data) == f"j{seq}".encode()
            fab.stream_ack("jobs", "g", [ev.seq])
        # the dead-lettered event travelled with its co-homed DLQ topic
        dev = fab.stream_take(dlq_topic("jobs"), "aud", timeout=5.0)
        assert bytes(dev.data) == b"j1" and dev.meta["i"] == 1
        assert dev.meta["dlq"]["seq"] == 1
        assert dev.meta["dlq"]["reason"] == "poison"
        stat = fab.stream_stat("jobs")
        assert stat["count"] == 6 and stat["max_deliveries"] == 1
        # the stream stays live across both membership changes
        fab.stream_append("jobs", b"j6", meta={"i": 6})
        ev = fab.stream_take("jobs", "g", timeout=5.0)
        assert ev.seq == 6 and bytes(ev.data) == b"j6"
    finally:
        extra.stop()


# ---------------------------------------------------------------------------
# chaos tier: at-least-once across failover + poison -> DLQ
# ---------------------------------------------------------------------------
def _retrying(fn, deadline_s=30.0):
    deadline = time.monotonic() + deadline_s
    while True:
        try:
            return fn()
        except (ConnectionError, TimeoutError, OSError):
            if time.monotonic() > deadline:
                raise
            time.sleep(0.05)


@pytest.mark.chaos
def test_failover_delivers_all_committed_and_dead_letters_poison(cluster):
    """SIGKILL the topic's home shard with a consumer group mid-stream:
    the group resumes from the replicated cursor and every committed
    event is delivered at least once (zero skipped seqs); the poison
    event dead-letters to ``<topic>.dlq`` after ``max_deliveries``."""
    handles, fab = cluster
    fab.stream_subscribe("t", "g")
    fab.stream_subscribe(dlq_topic("t"), "aud")
    fab.stream_limit("t", None, max_deliveries=2)
    committed: set[int] = set()
    poison_seq = None
    for i in range(6):
        meta = {"i": i, "poison": True} if i == 3 else {"i": i}
        seq = fab.stream_append("t", f"e{i}".encode(), meta=meta)
        committed.add(seq)
        if i == 3:
            poison_seq = seq
    home = fab._stream_home["t"]
    sched = crash_during_cursor_replication(_handle_for(home, handles),
                                            delay_s=0.05)
    for i in range(6, 12):                 # appends ride out the crash
        seq = _retrying(lambda i=i: fab.stream_append(
            "t", f"e{i}".encode(), meta={"i": i}))
        committed.add(seq)
    sched.join(10.0)
    assert sched.fired == ["kill-stream-home"]

    seen: dict[int, bytes] = {}
    poison_dead = False           # requeue returns 0 once it dead-letters
    deadline = time.monotonic() + 60.0
    while not (committed <= set(seen) and poison_dead):
        assert time.monotonic() < deadline, \
            f"missing seqs {sorted(committed - set(seen))}, " \
            f"poison_dead={poison_dead}"
        ev = _retrying(lambda: fab.stream_take("t", "g", timeout=10.0))
        seen[ev.seq] = bytes(ev.data) if ev.data is not None else b""
        if ev.meta.get("poison"):
            back = _retrying(lambda: fab.stream_requeue(
                "t", "g", [ev.seq], reason="poison"))
            if not back:
                poison_dead = True
        else:
            _retrying(lambda: fab.stream_ack("t", "g", [ev.seq]))
    # zero committed events skipped; duplicates are the permitted cost
    assert committed <= set(seen)
    assert seen[poison_seq] == b"e3"
    # the poison event keeps redelivering until max_deliveries, then
    # lands in the DLQ with its failure record
    dev = _retrying(lambda: fab.stream_take(dlq_topic("t"), "aud",
                                            timeout=15.0), deadline_s=60.0)
    assert bytes(dev.data) == b"e3" and dev.meta.get("poison")
    assert dev.meta["dlq"]["topic"] == "t"
    assert dev.meta["dlq"]["group"] == "g"
    assert fab.n_failovers > 0
    assert fab._stream_home["t"] != home
