"""Serving-engine tests: continuous batching correctness, per-request
sampling, proxy weight/KV/stream planes, and multi-process zero-copy
weight sharing (N workers -> ONE arena mapping)."""
import multiprocessing as mp
import threading
import time
import uuid

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import Store, borrow
from repro.core.connectors import FileConnector, SharedMemoryConnector
from repro.core.proxy import extract, get_factory, is_proxy
from repro.core.store import unregister_store
from repro.models.serve_paths import KVBlockPool, KVPoolExhausted
from repro.serve.engine import Request, ServeEngine, metrics_tap
from repro.train.checkpoints import ProxyCheckpointManager

CFG = ARCHS["qwen2.5-14b"].reduced().replace(dtype="float32", n_layers=2)


@pytest.fixture(scope="module")
def engine():
    eng = ServeEngine(CFG, max_batch=4, max_context=64, block_tokens=8)
    assert eng._continuous
    yield eng
    eng.close()


@pytest.fixture
def shm_store(tmp_path):
    name = f"serve-test-{uuid.uuid4().hex[:8]}"
    store = Store(name, SharedMemoryConnector(str(tmp_path / "shm")))
    yield store
    store.close()
    unregister_store(name)


def _prompt(rng, n):
    return list(map(int, rng.integers(1, CFG.vocab, size=n)))


def _solo(engine, req: Request) -> list[int]:
    """Reference output: the request alone through a lockstep B=1 run."""
    ref = ServeEngine(CFG, params=engine.params, max_batch=1,
                      max_context=engine.max_context)
    ref._continuous = False
    return ref.generate([Request(prompt=req.prompt,
                                 max_new_tokens=req.max_new_tokens)]
                        )["outputs"][0]


# ---------------------------------------------------------------------------
# continuous batching: mixed lengths, per-request temperature
# ---------------------------------------------------------------------------
def test_mixed_length_continuous_matches_solo(engine):
    """Rows with different prompt lengths AND different max_new_tokens,
    batched continuously, must each produce exactly the tokens a solo
    run produces — and stop at their OWN max_new_tokens."""
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=_prompt(rng, p), max_new_tokens=m)
            for p, m in [(5, 6), (9, 3), (7, 9), (12, 2)]]
    out = engine.generate(reqs)
    for req, toks in zip(reqs, out["outputs"]):
        assert len(toks) == req.max_new_tokens
        assert toks == _solo(engine, req)
    # six requests through four rows: slots recycle mid-run
    more = reqs + [Request(prompt=list(reqs[0].prompt), max_new_tokens=4),
                   Request(prompt=list(reqs[2].prompt), max_new_tokens=5)]
    out2 = engine.generate(more)
    assert [len(t) for t in out2["outputs"]] == \
        [r.max_new_tokens for r in more]
    assert out2["outputs"][:4] == out["outputs"]


def test_per_request_temperature(engine):
    """A greedy (temperature=0) row next to a hot row must stay exactly
    deterministic — sampling uses each row's OWN temperature, not
    reqs[0]'s."""
    rng = np.random.default_rng(23)
    prompt = _prompt(rng, 8)
    greedy = Request(prompt=list(prompt), max_new_tokens=6, temperature=0.0)
    hot = Request(prompt=list(prompt), max_new_tokens=6, temperature=1.5)
    ref = _solo(engine, greedy)
    for _ in range(2):   # fresh RNG draws each call; greedy row immune
        out = engine.generate([Request(prompt=list(prompt), max_new_tokens=6,
                                       temperature=0.0),
                               Request(prompt=list(prompt), max_new_tokens=6,
                                       temperature=1.5)])
        assert out["outputs"][0] == ref
        assert len(out["outputs"][1]) == hot.max_new_tokens


# ---------------------------------------------------------------------------
# weight plane: proxy-checkpoint restore feeds the engine
# ---------------------------------------------------------------------------
def test_engine_restores_weights_from_checkpoint_manager(engine, tmp_path):
    import jax

    name = f"serve-ckpt-{uuid.uuid4().hex[:8]}"
    store = Store(name, FileConnector(str(tmp_path / "data")))
    try:
        mgr = ProxyCheckpointManager(store, str(tmp_path / "ckpts"))
        host = jax.tree.map(np.asarray, engine.params)
        mgr.save(1, {"params": host})
        restored = ServeEngine(CFG, ckpts=mgr, max_batch=2, max_context=32)
        rng = np.random.default_rng(3)
        req = Request(prompt=_prompt(rng, 6), max_new_tokens=4)
        assert restored.generate([req])["outputs"][0] == _solo(engine, req)
        restored.close()
    finally:
        store.close()
        unregister_store(name)


# ---------------------------------------------------------------------------
# KV plane: refcounted block lifecycle + lease reclamation
# ---------------------------------------------------------------------------
def test_kv_blocks_released_after_completion(engine, shm_store):
    pool = KVBlockPool(shm_store, CFG, block_tokens=8, lease_ttl=None)
    k = np.ones((CFG.n_layers, 8, CFG.n_kv_heads, CFG.hd), np.float32)
    blocks = pool.put_prefill(k, k)
    assert pool.stats()["n_blocks"] == 1
    assert shm_store.refcount(blocks[0].key) == 1    # the pool's owning ref
    kk, vv = pool.gather(blocks)
    np.testing.assert_array_equal(kk, k)
    pool.release(blocks)                             # refcount -> 0 -> freed
    assert shm_store.refcount(blocks[0].key) == 0
    assert not shm_store.exists(blocks[0].key)
    assert pool.stats() == {**pool.stats(), "n_blocks": 0, "bytes_in_use": 0}

    # end-to-end: a generate() leaves the engine's pool empty
    rng = np.random.default_rng(5)
    engine.generate([Request(prompt=_prompt(rng, 10), max_new_tokens=12)])
    st = engine.kv_pool().stats()
    assert st["n_blocks"] == 0 and st["bytes_in_use"] == 0


def test_crashed_worker_blocks_reclaimed_by_lease(shm_store):
    """Blocks whose owner never calls release() (a crashed worker) are
    reclaimed once their lease expires, and the freed budget admits new
    requests again."""
    per_block = 2 * CFG.n_layers * 8 * CFG.n_kv_heads * CFG.hd * 4
    pool = KVBlockPool(shm_store, CFG, block_tokens=8,
                       budget_bytes=2 * per_block, lease_ttl=0.05)
    k = np.zeros((CFG.n_layers, 8, CFG.n_kv_heads, CFG.hd), np.float32)
    orphans = [pool.put_block(k, k), pool.put_block(k, k)]
    with pytest.raises(KVPoolExhausted):
        pool.put_block(k, k)                 # budget full, leases still live
    time.sleep(0.12)                         # the "worker" died; leases lapse
    assert pool.sweep() >= 1
    assert pool.stats()["bytes_in_use"] == 0
    for blk in orphans:
        assert not shm_store.exists(blk.key)
    fresh = pool.put_block(k, k)             # reclaimed budget is usable
    pool.release([fresh])


def test_starved_pool_defers_admission_and_completes_all(engine):
    """A pool that holds ~2 requests' pages must still complete 5 requests
    (admission defers until completions free blocks) with outputs equal to
    the unconstrained engine's."""
    rng = np.random.default_rng(17)
    reqs = [Request(prompt=_prompt(rng, 8), max_new_tokens=6)
            for _ in range(5)]
    want = engine.generate([Request(prompt=list(r.prompt),
                                    max_new_tokens=r.max_new_tokens)
                            for r in reqs])["outputs"]
    per_tok = 2 * CFG.n_layers * CFG.n_kv_heads * CFG.hd * 4
    tight = ServeEngine(CFG, params=engine.params, max_batch=4,
                        max_context=32, block_tokens=8,
                        kv_budget_bytes=2 * 16 * per_tok)   # ~2 requests
    out = tight.generate([Request(prompt=list(r.prompt),
                                  max_new_tokens=r.max_new_tokens)
                          for r in reqs])["outputs"]
    assert out == want
    tight.close()


# ---------------------------------------------------------------------------
# stream plane: requests in as proxies, completions out as evict proxies
# ---------------------------------------------------------------------------
def test_serve_stream_roundtrip(engine, shm_store):
    rng = np.random.default_rng(29)
    reqs = [Request(prompt=_prompt(rng, 7), max_new_tokens=5,
                    req_id=f"s-{i}") for i in range(5)]
    want = {r.req_id: t for r, t in zip(
        reqs, engine.generate([Request(prompt=list(r.prompt),
                                       max_new_tokens=r.max_new_tokens)
                               for r in reqs])["outputs"])}

    def feed():
        prod = shm_store.stream_producer("req")
        for r in reqs:
            prod.append(shm_store.proxy(
                {"prompt": r.prompt, "max_new_tokens": r.max_new_tokens,
                 "req_id": r.req_id}, ttl=30.0))
        prod.close()

    t = threading.Thread(target=feed)
    t.start()
    stats = engine.serve_stream(shm_store, "req", "res",
                                data_store=shm_store, timeout=30.0,
                                result_groups=("metrics",))
    t.join()
    assert stats["completed"] == len(reqs)
    got = {}
    for item in shm_store.stream_consumer("res", timeout=10.0):
        c = extract(item) if is_proxy(item) else item
        got[c["req_id"]] = c["tokens"]
        assert c["total_s"] >= c["queued_s"] >= 0.0
    assert got == want
    # completions published ONCE fan out to the pre-subscribed metrics
    # group too: the tap reads per-request metadata without resolving
    # (or stealing) a single result payload
    with metrics_tap(shm_store, "res", timeout=10.0) as tap:
        metas = {m["req_id"]: m["n_tokens"] for m in tap}
    assert metas == {rid: len(toks) for rid, toks in want.items()}


# ---------------------------------------------------------------------------
# multi-worker zero-copy weight sharing: N processes, ONE arena mapping
# ---------------------------------------------------------------------------
def _first_big_leaf(tree):
    """Deterministic walk to the first >=512-byte array leaf (PSJ2 ships
    arrays that size out-of-band as zero-copy views)."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            leaf = _first_big_leaf(tree[k])
            if leaf is not None:
                return leaf
        return None
    arr = np.asarray(tree)
    return arr if arr.nbytes >= 512 else None


def _weight_worker(borrowed, conn):
    tree = extract(borrowed)                 # zero-copy views of the slot
    leaf = _first_big_leaf(tree)
    conn.send((float(leaf.flat[0]), bool(leaf.flags["OWNDATA"])))
    conn.recv()                              # parent mutated its own view
    conn.send(float(leaf.flat[0]))           # same mapping -> sees the write
    conn.close()


def test_multi_worker_zero_copy_weight_sharing(engine, tmp_path):
    """N spawned workers resolve the same published weight proxy to views
    of ONE arena mapping: no worker owns its data, borrows add no
    references, and an in-place write through the publisher's view is
    visible to every worker without re-transfer."""
    name = f"serve-weights-{uuid.uuid4().hex[:8]}"
    store = Store(name, SharedMemoryConnector(str(tmp_path / "shm")))
    procs, pipes = [], []
    try:
        owned = engine.publish_weights(store, ttl=60.0)
        key = get_factory(owned).key
        assert store.refcount(key) == 1      # exactly the owner's reference

        ctx = mp.get_context("spawn")
        for _ in range(2):
            parent, child = ctx.Pipe()
            p = ctx.Process(target=_weight_worker,
                            args=(borrow(owned), child), daemon=True)
            p.start()
            child.close()
            procs.append(p)
            pipes.append(parent)
        first = [c.recv() for c in pipes]
        assert len({v for v, _ in first}) == 1
        assert all(not owndata for _, owndata in first)  # views, not copies
        assert store.refcount(key) == 1      # borrows carry no refs

        store.cache.pop(key)                 # bypass the put-side cache
        view = _first_big_leaf(store.get(key))
        assert not view.flags["OWNDATA"]     # publisher's view is shm too
        assert float(view.flat[0]) == first[0][0]
        view.flat[0] = 123.25                # in-place write into the slot
        for c in pipes:
            c.send("go")
        assert [c.recv() for c in pipes] == [123.25, 123.25]
        for p in procs:
            p.join(30)
            assert p.exitcode == 0
    finally:
        for p in procs:
            if p.is_alive():  # pragma: no cover - failed mid-protocol
                p.terminate()
        store.close()
        unregister_store(name)
