"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               ssd_scan_ref)
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def qkv(key, B, Sq, Skv, H, KV, HD, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, HD), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, HD), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, HD), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,KV,HD,causal,window", [
    (2, 256, 256, 4, 2, 64, True, 0),      # GQA causal
    (1, 128, 128, 8, 8, 128, True, 0),     # MHA, MXU-wide head
    (2, 256, 256, 4, 2, 64, False, 0),     # bidirectional (encoder)
    (1, 256, 256, 4, 1, 64, True, 128),    # MQA + sliding window
    (1, 384, 384, 2, 2, 32, True, 0),      # non-128 block tail (384=3*128)
])
def test_flash_attention_sweep(dtype, B, Sq, Skv, H, KV, HD, causal, window):
    q, k, v = qkv(jax.random.key(0), B, Sq, Skv, H, KV, HD, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,HD,length", [
    (2, 512, 8, 2, 64, 512),
    (2, 512, 8, 2, 64, 300),      # partially-valid (ring) cache
    (1, 1024, 4, 4, 128, 777),
    (4, 256, 2, 1, 64, 1),        # single valid entry
])
def test_decode_attention_sweep(dtype, B, S, H, KV, HD, length):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, HD), dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, HD), dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, HD), dtype)
    out = decode_attention(q, kc, vc, length)
    ref = decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (2, 128, 4, 8, 16, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 64, 3, 16, 8, 64),        # chunk == L
    (1, 512, 1, 32, 128, 128),    # long sequence, wide state
])
def test_ssd_scan_sweep(B, L, H, P, N, chunk):
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, L, N)) * 0.3
    c = jax.random.normal(ks[4], (B, L, N)) * 0.3
    out = ssd_scan(x, dt, a_log, b, c, chunk=chunk)
    ref = ssd_scan_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_ops_wrappers_differentiable():
    """custom_vjp wrappers: kernel forward + oracle-recompute backward."""
    from repro.kernels import ops

    ks = jax.random.split(jax.random.key(3), 3)
    q, k, v = qkv(jax.random.key(0), 1, 128, 128, 4, 2, 32, jnp.float32)

    def f(q, k, v):
        return ops.flash_attention(q, k, v, True, 0, 0).sum()

    g_kernel = jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    def f_ref(q, k, v):
        return flash_attention_ref(q, k, v, causal=True).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_flash_fully_masked_rows_are_zero():
    """window smaller than block: early rows attend to nothing beyond
    causal+window -> finite outputs, no NaN."""
    q, k, v = qkv(jax.random.key(4), 1, 256, 256, 2, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=16)
    assert np.isfinite(np.asarray(out)).all()
