"""PSJ2 multi-buffer frame format: zero-copy, dtypes, compat, compression.

The container may not have ``zstandard`` installed, so the compression
pathways are exercised against a zlib-backed stand-in monkeypatched into the
serializer's lazy-import slot — the same code paths run either way.
"""
import importlib
import zlib

import numpy as np
import pytest

from repro.core import (Frame, Store, deserialize, frame_nbytes, join_frame,
                        maybe_proxy, serialize, serialize_v1)
from repro.core.connectors import LocalMemoryConnector

S = importlib.import_module("repro.core.serialize")


# ---------------------------------------------------------------------------
# zero-copy guarantees
# ---------------------------------------------------------------------------
def test_serialize_is_zero_copy_for_large_contiguous_arrays():
    x = np.random.default_rng(0).standard_normal(64 * 1024 // 4) \
        .astype(np.float32)                       # 64 KiB, incompressible
    f = serialize({"w": x})
    # the payload segment aliases the array's own memory
    assert any(np.shares_memory(np.frombuffer(seg, np.uint8), x)
               for seg in f.segments if seg.nbytes == x.nbytes)
    # frame-path round trip: the output views the input array
    out = deserialize(f)["w"]
    np.testing.assert_array_equal(out, x)
    assert np.shares_memory(out, x)


def test_deserialize_is_zero_copy_over_received_frame():
    x = np.random.default_rng(1).standard_normal(100_000).astype(np.float32)
    wire = bytes(serialize(x))                    # the "received" frame
    out = deserialize(wire)
    np.testing.assert_array_equal(out, x)
    assert np.shares_memory(out, np.frombuffer(wire, np.uint8))
    assert not out.flags.writeable                # views of bytes: read-only
    # writable input -> writable zero-copy views
    out2 = deserialize(memoryview(bytearray(wire)))
    assert out2.flags.writeable
    np.testing.assert_array_equal(out2, x)


def test_buffers_are_64_byte_aligned():
    f = serialize([np.zeros(1000, np.float32), np.ones(2000, np.float64)])
    wire = bytes(f)
    nbuf = S._HEADER.unpack_from(wire, 0)[2]
    assert nbuf == 2
    for i in range(nbuf):
        offset = S._TABLE.unpack_from(wire, S._HEADER.size + 32 * i)[0]
        assert offset % 64 == 0


def test_small_arrays_ride_inline():
    f = serialize(np.arange(8))                   # < 512 B: no OOB buffer
    assert S._HEADER.unpack_from(bytes(f), 0)[2] == 0
    out = deserialize(f)
    np.testing.assert_array_equal(out, np.arange(8))
    assert out.flags.writeable                    # inline arrays own memory


# ---------------------------------------------------------------------------
# round-trip matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype_name", ["bfloat16", "float8_e4m3fn",
                                        "float8_e5m2"])
@pytest.mark.parametrize("n", [16, 4096])         # inline and out-of-band
def test_extension_dtypes(dtype_name, n):
    import ml_dtypes

    dtype = getattr(ml_dtypes, dtype_name)
    x = np.linspace(0, 1, n).astype(dtype).reshape(4, -1)
    for wire in (serialize(x), bytes(serialize(x)), serialize_v1(x)):
        out = deserialize(wire)
        assert str(out.dtype) == dtype_name and out.shape == x.shape
        np.testing.assert_array_equal(out.astype(np.float32),
                                      x.astype(np.float32))


def test_non_contiguous_arrays():
    base = np.arange(40_000, dtype=np.float32).reshape(200, 200)
    views = [base[::2, ::3], base.T, base[5:190, 7:]]
    for v in views:
        assert not v.flags.c_contiguous
        out = deserialize(bytes(serialize(v)))
        np.testing.assert_array_equal(out, v)


def test_fortran_order_and_zero_size():
    f_ord = np.asfortranarray(np.arange(10_000, dtype=np.float64)
                              .reshape(100, 100))
    np.testing.assert_array_equal(deserialize(serialize(f_ord)), f_ord)
    empty = np.zeros((0, 7), np.float32)
    out = deserialize(bytes(serialize(empty)))
    assert out.shape == (0, 7) and out.dtype == np.float32


def test_proxies_nested_in_pytrees():
    from functools import partial

    from repro.core import Proxy, is_proxy, is_resolved

    big = np.random.default_rng(2).standard_normal(50_000).astype(np.float32)
    p = Proxy(partial(int, 41))
    tree = {"a": [big, {"p": p}], "b": (p, "x")}
    out = deserialize(bytes(serialize(tree)))
    assert not is_resolved(p)                     # serializer never resolves
    np.testing.assert_array_equal(out["a"][0], big)
    assert is_proxy(out["a"][1]["p"])
    assert out["a"][1]["p"] + 1 == 42             # resolves transparently
    assert out["b"][1] == "x"


def test_psj1_frames_still_deserialize():
    tree = {"w": np.arange(10_000, dtype=np.float32).reshape(100, 100),
            "meta": (1, "two", {3, 4})}
    legacy = serialize_v1(tree)
    assert legacy[:4] == b"PSJ1"
    out = deserialize(legacy)
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert out["meta"] == tree["meta"]


def test_bad_magic_rejected():
    with pytest.raises(ValueError, match="magic"):
        deserialize(b"NOPE" + b"\x00" * 40)


# ---------------------------------------------------------------------------
# compression: on / off / unavailable
# ---------------------------------------------------------------------------
class _FakeZstd:
    """zlib-backed stand-in with the zstandard surface the serializer uses."""

    class ZstdCompressor:
        def __init__(self, level=3):
            self.level = level

        def compress(self, data):
            return zlib.compress(bytes(data), 6)

    class ZstdDecompressor:
        def decompress(self, data, max_output_size=0):
            return zlib.decompress(bytes(data))


@pytest.fixture
def fake_zstd(monkeypatch):
    monkeypatch.setattr(S, "_zstd", _FakeZstd)
    return _FakeZstd


@pytest.fixture
def no_zstd(monkeypatch):
    monkeypatch.setattr(S, "_zstd", None)


def test_per_buffer_compression(fake_zstd):
    compressible = np.zeros(200_000, np.float32)
    incompressible = np.random.default_rng(3).standard_normal(50_000) \
        .astype(np.float32)
    f = serialize({"z": compressible, "r": incompressible})
    # zeros shrink, random floats are stored raw — per-buffer decisions
    assert f.nbytes < compressible.nbytes + incompressible.nbytes
    assert f.nbytes > incompressible.nbytes
    bflags = {S._TABLE.unpack_from(bytes(f),
                                   S._HEADER.size + 32 * i)[3]
              for i in range(2)}
    assert bflags == {0, S._BUF_ZSTD}
    out = deserialize(bytes(f))
    np.testing.assert_array_equal(out["z"], compressible)
    np.testing.assert_array_equal(out["r"], incompressible)


def test_compress_flag_forced_and_disabled(fake_zstd):
    z = np.zeros(100_000, np.float32)
    assert serialize(z, compress=True).nbytes < z.nbytes
    assert serialize(z, compress=False).nbytes > z.nbytes
    for flag in (True, False, None):
        np.testing.assert_array_equal(
            deserialize(bytes(serialize(z, compress=flag))), z)
    # forcing compresses even sub-threshold buffers (auto mode skips them)
    small = np.zeros(1024, np.float32)                # 4 KiB, compressible
    assert serialize(small, compress=True).nbytes < \
        serialize(small, compress=None).nbytes
    np.testing.assert_array_equal(
        deserialize(bytes(serialize(small, compress=True))), small)


def test_truncated_frames_raise_value_error():
    wire = bytes(serialize(np.zeros(100_000, np.float32)))
    for cut in (5, 16, 30, len(wire) // 2):           # header/table/payload
        with pytest.raises(ValueError, match="truncated"):
            deserialize(wire[:cut])


def test_zstd_absent_falls_back_to_uncompressed(no_zstd):
    z = np.zeros(100_000, np.float32)
    f = serialize(z, compress=True)               # asked, but unavailable
    assert f.nbytes > z.nbytes                    # stored raw, no error
    np.testing.assert_array_equal(deserialize(bytes(f)), z)
    assert serialize_v1(z, compress=True)[4] & 1 == 0


def test_decompress_without_zstd_raises_actionable_error(monkeypatch):
    z = np.zeros(100_000, np.float32)
    monkeypatch.setattr(S, "_zstd", _FakeZstd)
    compressed_v2 = bytes(serialize(z, compress=True))
    compressed_v1 = serialize_v1(z, compress=True)
    monkeypatch.setattr(S, "_zstd", None)
    for frame in (compressed_v2, compressed_v1):
        with pytest.raises(RuntimeError, match="zstandard"):
            deserialize(frame)


# ---------------------------------------------------------------------------
# store integration
# ---------------------------------------------------------------------------
def test_maybe_proxy_respects_custom_serializer():
    """A Store with custom serializer/deserializer hooks must produce
    proxies that resolve through those same hooks (bugfix)."""
    import pickle

    calls = {"ser": 0, "de": 0}

    def ser(obj):
        calls["ser"] += 1
        return b"CUSTOM" + pickle.dumps(obj)

    def de(blob):
        calls["de"] += 1
        return pickle.loads(join_frame(blob)[6:])

    s = Store("psj2-custom", LocalMemoryConnector(), serializer=ser,
              deserializer=de, register=True)
    try:
        big = list(range(10_000))
        p = maybe_proxy(s, big, threshold_bytes=100)
        assert calls["ser"] == 1                  # serialized exactly once
        assert list(p) == big                     # resolves via custom hooks
        assert calls["de"] == 1
        small = maybe_proxy(s, [1], threshold_bytes=10_000)
        assert small == [1]
    finally:
        s.close()


def test_store_roundtrip_hands_out_views(tmp_path):
    from repro.core.connectors import FileConnector

    s = Store("psj2-views", FileConnector(str(tmp_path / "d")),
              register=False)
    x = np.random.default_rng(4).standard_normal(100_000).astype(np.float32)
    key = s.put({"x": x})
    out = s.get(key)["x"]
    np.testing.assert_array_equal(out, x)
    import jax.numpy as jnp

    j = jnp.asarray(out)                          # zero host-side copies
    np.testing.assert_array_equal(np.asarray(j), x)


def test_frame_nbytes_helpers():
    f = serialize(np.arange(65_536, dtype=np.float32))
    assert frame_nbytes(f) == len(bytes(f)) == len(join_frame(f))
    assert frame_nbytes(b"abc") == 3
    assert frame_nbytes([memoryview(b"ab"), memoryview(b"cde")]) == 5
    assert join_frame([memoryview(b"ab"), b"cde"]) == b"abcde"
    assert isinstance(f, Frame)
