"""Broker-backed pub/sub stream plane (multi-consumer fanout).

LocalBroker full semantics, the KV wire path (per-group payload
refcounts with evict-after-last-ack, filtered metadata taps that never
touch the data plane, credit-based backpressure), the Store shim that
keeps PR-4 ``stream_producer``/``stream_consumer`` behavior byte-
identical under a single group, location addressing errors, and
consumer-group failover on the sharded fabric (chaos tier).
"""
import os
import signal
import threading
import time
import uuid

import pytest

from repro.core import Store
from repro.core.connectors import KVServerConnector, LocalMemoryConnector
from repro.core.kv_tcp import KVClient, spawn_server, stream_item_key
from repro.core.store import unregister_store
from repro.stream import LocalBroker, StreamConsumer, StreamProducer
from repro.stream.filters import compile_filter


# ---------------------------------------------------------------------------
# LocalBroker: full broker semantics, no server
# ---------------------------------------------------------------------------
def test_local_fanout_exactly_once_and_evict_after_last_ack():
    b = LocalBroker()
    b.subscribe("t", "a")
    b.subscribe("t", "b")
    seqs = [b.publish("t", f"i{i}".encode()) for i in range(3)]
    got_a = [b.take("t", "a", timeout=1) for _ in range(3)]
    assert [bytes(e.data) for e in got_a] == [b"i0", b"i1", b"i2"]
    b.ack("t", "a", [e.seq for e in got_a])
    t = b._topics["t"]
    assert len(t.data) == 3            # group b has not acked: retained
    got_b = b.take_batch("t", "b", 10)
    assert [e.seq for e in got_b] == seqs
    b.ack("t", "b", seqs)
    assert t.data == {} and t.owners == {}   # LAST ack evicts
    with pytest.raises(TimeoutError):        # exactly once per group
        b.take("t", "a", timeout=0.05)


def test_local_filtered_group_and_unmatched_never_stored():
    b = LocalBroker()
    b.subscribe("t", "big", filter={"key": "n", "op": ">=", "value": 10})
    for n in (3, 12, 7, 20):
        b.publish("t", f"v{n}".encode(), meta={"n": n})
    t = b._topics["t"]
    # events no group wants were never stored (count still advances)
    assert t.count == 4 and set(t.data) == {1, 3}
    evs = [b.take("t", "big", timeout=1) for _ in range(2)]
    assert [e.meta["n"] for e in evs] == [12, 20]
    b.ack("t", "big", [e.seq for e in evs])
    assert t.data == {}


def test_local_backpressure_parks_and_acks_release():
    b = LocalBroker()
    b.subscribe("bp", "g")
    b.set_limit("bp", 2)
    b.publish("bp", b"0")
    b.publish("bp", b"1")
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        b.publish("bp", b"2", timeout=0.2)
    assert time.monotonic() - t0 >= 0.2

    def drain():
        time.sleep(0.2)
        ev = b.take("bp", "g", timeout=5)
        b.ack("bp", "g", [ev.seq])

    th = threading.Thread(target=drain)
    th.start()
    # released by the ack; the timed-out publish was never committed,
    # so the next sequence number is 2
    assert b.publish("bp", b"2", timeout=10) == 2
    th.join(5)


def test_local_unsubscribe_releases_references():
    b = LocalBroker()
    b.subscribe("t", "a")
    b.subscribe("t", "b")
    b.publish("t", b"x")
    ev = b.take("t", "a", timeout=1)
    b.ack("t", "a", [ev.seq])
    assert b._topics["t"].data            # b still holds a reference
    b.unsubscribe("t", "b")
    assert b._topics["t"].data == {}


def test_consumer_close_requeues_prefetched_to_group():
    b = LocalBroker()
    with StreamProducer(b, "q") as prod:
        for i in range(5):
            prod.append(f"m{i}".encode())
    c1 = StreamConsumer(b, "q", "g", start="begin", prefetch=8, timeout=1)
    assert bytes(next(c1)) == b"m0"
    assert c1.pending() == 4              # prefetched, NOT yet acked
    c1.close()                            # requeues the 4 to the group
    c2 = StreamConsumer(b, "q", "g", prefetch=0, timeout=1)
    assert [bytes(x) for x in c2] == [b"m1", b"m2", b"m3", b"m4"]
    c2.close()
    with pytest.raises(RuntimeError):     # closed consumer refuses takes
        next(c1)


def test_filter_spec_validation_and_semantics():
    fn = compile_filter({"any": [{"key": "a", "op": "==", "value": 1},
                                 {"not": {"key": "b"}}]})
    assert fn({"a": 1, "b": 0}) and fn({}) and not fn({"a": 2, "b": 1})
    assert compile_filter({"key": "k", "op": "!="})({})     # missing: True
    assert not compile_filter({"key": "k", "op": ">", "value": 1})({"k": "s"})
    with pytest.raises(ValueError):
        compile_filter({"key": "k", "op": "~="})
    with pytest.raises(ValueError):
        compile_filter({"op": "=="})


# ---------------------------------------------------------------------------
# KV wire path: per-group refcounts on the lifetime table
# ---------------------------------------------------------------------------
@pytest.fixture()
def kv(tmp_path):
    host, port, pid = spawn_server(ready_file=str(tmp_path / "kv.ready"))
    client = KVClient(host, port)
    yield client
    client.shutdown_server()
    client.close()
    try:
        os.kill(pid, signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        pass


def test_kv_fanout_refcount_and_evict_after_last_ack(kv):
    kv.stream_sub("f", "a")
    kv.stream_sub("f", "b")
    kv.stream_append("f", b"x")
    key = stream_item_key("f", 0)
    assert kv.refcount(key) == 2          # one reference per matching group
    ita = kv.stream_take("f", "a", timeout=5)
    itb = kv.stream_take("f", "b", timeout=5)
    assert bytes(ita["data"]) == b"x" == bytes(itb["data"])
    assert kv.stream_ack("f", "a", [0]) == 1
    assert kv.exists(key)                 # group b still holds it
    assert kv.stream_ack("f", "b", [0]) == 1
    assert not kv.exists(key)             # last ack: evicted exactly once
    assert kv.stream_ack("f", "b", [0]) == 0   # idempotent


def test_kv_filtered_tap_serves_zero_payloads(kv):
    kv.stream_sub("m", "main")
    kv.stream_sub("m", "tap", filter={"key": "i", "op": ">=", "value": 2})
    for i in range(4):
        kv.stream_append("m", f"p{i}".encode(), meta={"i": i})
    base = kv.stats()["n_payload_serves"]
    evs = [kv.stream_take("m", "tap", timeout=5, payload=False)
           for _ in range(2)]
    assert [e["meta"]["i"] for e in evs] == [2, 3]
    assert all(e["data"] is None for e in evs)
    assert kv.stream_ack("m", "tap", [e["seq"] for e in evs]) == 2
    # the metadata-only tap crossed ZERO payload bytes
    assert kv.stats()["n_payload_serves"] == base
    it = kv.stream_take("m", "main", timeout=5)      # main group resolves
    assert bytes(it["data"]) == b"p0"
    assert kv.stats()["n_payload_serves"] == base + 1


def test_kv_begin_subscribe_adopts_retained_events(kv):
    for i in range(3):
        kv.stream_append("pre", f"e{i}".encode())    # legacy: no groups yet
    st = kv.stream_sub("pre", "late", start="begin")
    assert st["queued"] == 3
    got = kv.stream_take_batch("pre", "late", 10)
    assert [bytes(e["data"]) for e in got] == [b"e0", b"e1", b"e2"]
    kv.stream_ack("pre", "late", [e["seq"] for e in got])
    assert not kv.exists(stream_item_key("pre", 0))
    # start="new" skips history
    assert kv.stream_sub("pre", "fresh", start="new")["queued"] == 0


def test_kv_backpressure_park_and_release(kv):
    kv.stream_sub("bp", "g")
    kv.stream_limit("bp", 2)
    assert kv.stream_append("bp", b"0") == 0
    assert kv.stream_append("bp", b"1") == 1
    with pytest.raises(TimeoutError):     # buffer full: append parks
        kv.stream_append("bp", b"2", timeout=0.3)

    def drain():
        time.sleep(0.3)
        it = kv.stream_take("bp", "g", timeout=5)
        kv.stream_ack("bp", "g", [it["seq"]])

    th = threading.Thread(target=drain)
    th.start()
    # the ack frees a credit and un-parks the append (timed-out append
    # above was never committed: the next sequence number is 2)
    assert kv.stream_append("bp", b"2", timeout=10) == 2
    th.join(5)


def test_kv_requeue_redelivers_in_order(kv):
    kv.stream_sub("rq", "g")
    for i in range(3):
        kv.stream_append("rq", f"r{i}".encode())
    taken = [kv.stream_take("rq", "g", timeout=5) for _ in range(3)]
    assert kv.stream_requeue("rq", "g", [t["seq"] for t in taken[1:]]) == 2
    again = kv.stream_take_batch("rq", "g", 10)
    assert [bytes(e["data"]) for e in again] == [b"r1", b"r2"]


# ---------------------------------------------------------------------------
# Store shim: PR-4 call sites run unchanged on the broker plane
# ---------------------------------------------------------------------------
@pytest.fixture()
def mem_store():
    name = f"stream-plane-{uuid.uuid4().hex[:8]}"
    store = Store(name, LocalMemoryConnector())
    yield store
    store.close()
    unregister_store(name)


def test_shim_single_group_round_trip(mem_store):
    with mem_store.stream_producer("s") as prod:
        for i in range(5):
            prod.append({"i": i})
    assert [o["i"] for o in mem_store.stream_consumer("s", timeout=5)] \
        == [0, 1, 2, 3, 4]


def test_shim_exception_delivered_in_order(mem_store):
    with mem_store.stream_producer("x") as prod:
        prod.append(1)
        prod.append_exception(ValueError("boom"))
        prod.append(3)
    stream = mem_store.stream_consumer("x", timeout=5, prefetch=0)
    assert next(stream) == 1
    with pytest.raises(ValueError, match="boom"):
        next(stream)
    assert next(stream) == 3
    with pytest.raises(StopIteration):
        next(stream)


def test_store_fanout_tap_steals_nothing(mem_store):
    tap = mem_store.stream_consumer("r", group="tap", payload=False,
                                    timeout=5)
    with mem_store.stream_producer("r") as prod:
        for i in range(3):
            prod.append({"i": i}, meta={"i": i})
    main = [o["i"] for o in mem_store.stream_consumer("r", group="client",
                                                      timeout=5)]
    assert main == [0, 1, 2]              # full payloads, nothing stolen
    assert [m["i"] for m in tap] == [0, 1, 2]   # metadata only
    tap.close()


def test_store_consumer_close_requeues(mem_store):
    with mem_store.stream_producer("q") as prod:
        for i in range(6):
            prod.append(i)
    c1 = mem_store.stream_consumer("q", timeout=5, prefetch=8)
    assert next(c1) == 0
    assert c1.pending() == 5
    c1.close()
    assert list(mem_store.stream_consumer("q", timeout=5)) == [1, 2, 3, 4, 5]


def test_store_location_rejected_without_support(mem_store):
    with pytest.raises(ValueError, match="location"):
        mem_store.stream_consumer("t", location="node-1")


def test_kvserver_store_fanout_and_filter(kv, tmp_path):
    name = f"stream-kv-{uuid.uuid4().hex[:8]}"
    store = Store(name, KVServerConnector(kv.host, kv.port))
    try:
        # both groups subscribe BEFORE publishing: an event matched by
        # no group at publish time is never stored at all
        slow = store.stream_consumer("jobs", group="slow",
                                     filter={"key": "p", "op": ">",
                                             "value": 0}, timeout=5)
        every_c = store.stream_consumer("jobs", group="all", timeout=5)
        with store.stream_producer("jobs") as prod:
            for i in range(4):
                prod.append({"job": i}, meta={"p": i % 2})
        assert [o["job"] for o in every_c] == [0, 1, 2, 3]
        assert [o["job"] for o in slow] == [1, 3]
        slow.close()
    finally:
        store.close()
        unregister_store(name)


# ---------------------------------------------------------------------------
# chaos tier: consumer-group failover on the sharded fabric
# ---------------------------------------------------------------------------
@pytest.mark.chaos
def test_fabric_stream_group_survives_shard_kill(tmp_path):
    """Kill the topic's home shard mid-stream: the group re-homes to a
    replica that already holds the chained events AND the replicated
    group cursor, and resumes at-least-once — redelivery of in-flight
    events is allowed, a skipped committed event is the bug."""
    from repro.core.deploy import start_kvserver
    from repro.core.fabric import ShardedConnector
    from repro.distributed.chaos import kill_shard

    handles = [start_kvserver(str(tmp_path), name=f"s{i}", uds=True)
               for i in range(4)]
    fab = ShardedConnector([h.host for h in handles], replication=2,
                           quorum=True, op_timeout=5.0)
    try:
        fab.stream_subscribe("events", "workers")
        for i in range(3):                     # committed before the kill
            fab.stream_append("events", f"e{i}".encode())
        ev = fab.stream_take("events", "workers", timeout=5.0)
        assert bytes(ev.data) == b"e0"
        fab.stream_ack("events", "workers", [ev.seq])

        home = fab._stream_home["events"]
        victim = next(h for h in handles if h.host == home)
        kill_shard(victim)

        # appends fail over to the replica holding the restored cursor
        deadline = time.monotonic() + 30.0
        while True:
            try:
                fab.stream_append("events", b"e3")
                break
            except (ConnectionError, TimeoutError):
                if time.monotonic() > deadline:
                    raise
        # every committed-but-unacked event (1, 2) plus the post-kill
        # append (3) must be delivered; a redelivery of acked seq 0 is a
        # permitted duplicate (the consumer dedup-by-seq contract)
        seen: dict[int, bytes] = {}
        while not {1, 2, 3} <= set(seen):
            ev = fab.stream_take("events", "workers", timeout=10.0)
            seen[ev.seq] = bytes(ev.data) if ev.data is not None else b""
            fab.stream_ack("events", "workers", [ev.seq])
        assert seen[1] == b"e1" and seen[2] == b"e2" and seen[3] == b"e3"
        assert fab.n_failovers > 0
        assert fab._stream_home["events"] != home
    finally:
        fab.close()
        for h in handles:
            h.stop()
