"""Connector contract tests across every implementation + MultiConnector."""
import os
import time

import pytest

try:  # optional: property tests only run when hypothesis is installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import MultiConnector, NoConnectorMatch, Policy
from repro.core.connectors import (FileConnector, GlobusConnector,
                                   KVServerConnector, LocalMemoryConnector,
                                   SharedMemoryConnector, SocketConnector)
from repro.core.deploy import start_kvserver


def contract(conn):
    """The four-op Connector contract (paper §3.4)."""
    key = conn.put(b"hello world")
    assert conn.exists(key)
    assert conn.get(key) == b"hello world"
    assert conn.get(key) == b"hello world"   # write-once, read-many
    conn.evict(key)
    assert not conn.exists(key)
    assert conn.get(key) is None
    # batch ops
    keys = conn.put_batch([b"a", b"bb", b"ccc"])
    assert conn.get_batch(keys) == [b"a", b"bb", b"ccc"]
    conn.evict_batch(keys)
    assert conn.exists_batch(keys) == [False] * 3
    # empty payload + binary safety
    k = conn.put(bytes(range(256)))
    assert conn.get(k) == bytes(range(256))
    # config round trip reaches the same data
    k2 = conn.put(b"shared")
    clone = type(conn)(**conn.config())
    assert clone.get(k2) == b"shared"


def test_memory(tmp_path):
    contract(LocalMemoryConnector())


def test_file(tmp_path):
    contract(FileConnector(str(tmp_path / "files")))


def test_shm(tmp_path):
    conn = SharedMemoryConnector(str(tmp_path / "shm"))
    try:
        contract(conn)
    finally:
        conn.close()


def test_socket_spawned(tmp_path):
    conn = SocketConnector(str(tmp_path / "disc"))
    try:
        contract(conn)
    finally:
        conn.shutdown_server()


def test_kvserver_and_persistence(tmp_path):
    h = start_kvserver(str(tmp_path), persist_dir=str(tmp_path / "p"))
    conn = KVServerConnector(h.host, h.port)
    contract(conn)
    key = conn.put(b"durable")
    h.stop()
    h2 = start_kvserver(str(tmp_path), name="kv2",
                        persist_dir=str(tmp_path / "p"))
    conn2 = KVServerConnector(h2.host, h2.port)
    assert conn2.get(key) == b"durable"
    h2.stop()


def test_globus_sim(tmp_path):
    conn = GlobusConnector({"a": str(tmp_path / "a"), "b": str(tmp_path / "b")},
                           site="a", latency_s=0.05, bandwidth_mbps=1000)
    contract(conn)
    # transfer-task gating: availability delayed by the latency model
    t0 = time.time()
    key = conn.put(b"x" * 1000)
    conn.wait_task(key[2])
    assert time.time() - t0 >= 0.05
    # consumer at the other site sees the staged object
    consumer = GlobusConnector(conn.endpoint_map, site="b", latency_s=0.0)
    assert consumer.get(key) == b"x" * 1000
    # batch put files ONE transfer task
    keys = conn.put_batch([b"1", b"2", b"3"])
    assert len({k[2] for k in keys}) == 1


def test_globus_failure(tmp_path):
    from repro.core.connectors.globus import TransferError

    conn = GlobusConnector({"a": str(tmp_path / "a")}, site="a",
                           latency_s=0.0, fail_rate=1.0)
    key = conn.put(b"doomed")
    with pytest.raises(TransferError):
        conn.get(key)


def test_multiconnector_routing(tmp_path):
    mc = MultiConnector([
        (LocalMemoryConnector(), Policy(max_size=1000, priority=10,
                                        tags=frozenset({"local"}))),
        (FileConnector(str(tmp_path / "f")),
         Policy(priority=0, tags=frozenset({"local", "persistent"}))),
    ])
    assert mc.put(b"x" * 10)[1] == 0
    assert mc.put(b"x" * 5000)[1] == 1
    assert mc.put(b"x", constraints=["persistent"])[1] == 1
    with pytest.raises(NoConnectorMatch):
        mc.put(b"x", constraints=["nonexistent"])
    keys = mc.put_batch([b"s", b"x" * 5000])
    assert keys[0][1] == 0 and keys[1][1] == 1
    assert mc.get_batch(keys) == [b"s", b"x" * 5000]
    clone = MultiConnector(None, **mc.config())
    assert clone.get(keys[1]) == b"x" * 5000


def test_multiconnector_empty_raises():
    """No children must be a loud ValueError, not an -O-strippable assert."""
    with pytest.raises(ValueError, match="at least one"):
        MultiConnector([])
    with pytest.raises(ValueError, match="at least one"):
        MultiConnector(None)
    with pytest.raises(ValueError, match="at least one"):
        MultiConnector()


def test_put_batch_streams_frames_kvserver_and_socket(tmp_path):
    """Regression: PSJ2 Frames through put_batch/get_batch on the KV-backed
    connectors.  The old mput embedded blobs in msgpack, so a Frame either
    crashed packb or silently forced a join copy; mput2 streams the raw
    segments out of band."""
    import numpy as np

    from repro.core import deserialize, serialize

    h = start_kvserver(str(tmp_path))
    conns = [KVServerConnector(h.host, h.port),
             SocketConnector(str(tmp_path / "disc"))]
    arrays = [np.random.default_rng(i).standard_normal(3000) for i in range(5)]
    try:
        for conn in conns:
            keys = conn.put_batch([serialize(a) for a in arrays])
            blobs = conn.get_batch(keys)
            for a, blob in zip(arrays, blobs):
                np.testing.assert_array_equal(deserialize(blob), a)
            assert conn.exists_batch(keys) == [True] * len(keys)
            conn.evict_batch(keys)
            assert conn.exists_batch(keys) == [False] * len(keys)
    finally:
        conns[1].shutdown_server()
        h.stop()


def test_multiconnector_batch_dispatch(tmp_path):
    """get_batch/exists_batch/evict_batch route each key to its child and
    issue one batch op per child."""
    mc = MultiConnector([
        (LocalMemoryConnector(), Policy(max_size=1000, priority=10)),
        (FileConnector(str(tmp_path / "f")), Policy(priority=0)),
    ])
    blobs = [b"s1", b"x" * 5000, b"s2", b"y" * 5000]
    keys = mc.put_batch(blobs)
    assert [k[1] for k in keys] == [0, 1, 0, 1]
    assert mc.get_batch(keys) == blobs
    assert mc.exists_batch(keys) == [True] * 4
    mc.evict_batch(keys)
    assert mc.exists_batch(keys) == [False] * 4


def test_multiconnector_routes_frames(tmp_path):
    """Policy routing sees the frame's wire size, not its segment count."""
    import numpy as np

    from repro.core import deserialize, serialize

    mc = MultiConnector([
        (LocalMemoryConnector(), Policy(max_size=1000, priority=10)),
        (FileConnector(str(tmp_path / "f")), Policy(priority=0)),
    ])
    big = np.random.default_rng(0).standard_normal(10_000).astype(np.float32)
    key = mc.put(serialize(big))
    assert key[1] == 1                       # 40 KB frame -> file child
    np.testing.assert_array_equal(deserialize(mc.get(key)), big)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(size=st.integers(min_value=0, max_value=20_000),
           constraints=st.sets(st.sampled_from(["local", "persistent"]),
                               max_size=2))
    def test_property_multi_policy_invariant(tmp_path_factory, size,
                                             constraints):
        """Whatever is stored is retrievable, and the chosen child satisfies
        every constraint and the size bounds of its policy."""
        tmp = tmp_path_factory.mktemp("multi")
        policies = [Policy(max_size=1000, priority=5,
                           tags=frozenset({"local"})),
                    Policy(priority=1, tags=frozenset({"local",
                                                       "persistent"}))]
        mc = MultiConnector([
            (LocalMemoryConnector(), policies[0]),
            (FileConnector(str(tmp / "f")), policies[1]),
        ])
        blob = b"z" * size
        key = mc.put(blob, constraints=sorted(constraints))
        chosen = policies[key[1]]
        assert chosen.accepts(len(blob), frozenset(constraints))
        assert mc.get(key) == blob
