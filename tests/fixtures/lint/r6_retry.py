"""R6 fixture: non-idempotent KV ops riding retries.  Linted by tests,
never imported."""


@with_retries                                 # noqa: F821 - AST fixture
def bad_decref_in_retry(client, key):
    return client.decref(key)                 # FIRES: retry-decorated scope


def bad_forced_retry(client):
    client.request({"op": "decref", "key": "k"}, retry=True)   # FIRES
    return client.put2("k", b"v", retry=True)                  # FIRES


def bad_wrapped(client, key):
    return with_retries(lambda: client.s_append(key, b"x"))    # noqa: F821


def ok_idempotent(client, key):
    return client.get2(key)


@with_retries                                 # noqa: F821
def ok_allowlisted(client, key):
    return client.decref(key)  # lint: retry-ok
