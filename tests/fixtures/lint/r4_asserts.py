"""R4 fixture: bare asserts guarding runtime invariants (the test lints
this source AS IF it lived under src/repro/core/).  Never imported."""


def bad_guard(frame):
    assert frame, "empty frame"               # FIRES under a core path
    return frame


def ok_allowlisted(frame):
    assert frame is not None  # lint: assert-ok
    return frame
