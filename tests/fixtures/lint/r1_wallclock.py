"""R1 fixture: wall-clock in deadline arithmetic fires; timestamps
allowlist.  Linted by tests, never imported."""
import time
from time import time as now


def bad_deadline(timeout):
    deadline = time.time() + timeout          # FIRES: arithmetic
    while time.time() < deadline:             # FIRES: comparison
        pass


def bad_alias(t0):
    return now() - t0                         # FIRES: through the alias


def ok_manifest():
    return {"ts": time.time()}  # lint: wallclock-ok
