"""R7 fixture: stream consumers iterated without close().  Linted by
tests, never imported."""


def bad_for_loop(store):
    stream = store.stream_consumer("t")
    out = []
    for item in stream:                       # FIRES: no close anywhere
        out.append(item)
    return out


def bad_inline_drain(store):
    total = 0
    for item in store.stream_consumer("t"):   # FIRES: no handle to close
        total += 1
    return total


def bad_list_drain(store):
    tap = metrics_tap(store, "res")           # noqa: F821 - AST fixture
    return list(tap)                          # FIRES: drained, never closed


def ok_with_block(store):
    with store.stream_consumer("t") as stream:
        return [item for item in stream]


def ok_with_named(store):
    stream = store.stream_consumer("t")
    with stream:
        return list(stream)


def ok_try_finally(store):
    stream = store.stream_consumer("t")
    try:
        return next(stream)
    finally:
        stream.close()


def ok_allowlisted(store):
    stream = store.stream_consumer("t")       # exhausted streams self-drain
    return [x for x in stream]  # lint: stream-ok
