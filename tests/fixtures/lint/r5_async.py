"""R5 fixture: blocking calls inside async defs (the test lints this
source AS IF it were kv_tcp.py).  Never imported."""
import time


async def bad_handler(sock, path):
    time.sleep(0.1)                           # FIRES: sleeps the loop
    data = open(path)                         # FIRES: sync file I/O
    sock.sendall(data)                        # FIRES: sync socket op
    return data


async def ok_allowlisted(path):
    open(path)  # lint: blocking-ok
    return None


def ok_sync_scope(path):
    time.sleep(0.0)                           # not async: fine
    return open(path)
