"""R3 fixture: evict=True proxies double-resolved / pickled into a
fan-out.  Linted by tests, never imported."""
import pickle


def bad_double_resolve(store, obj):
    p = store.proxy(obj, evict=True)
    a = extract(p)                            # noqa: F821 - consumes the ref
    b = extract(p)                            # noqa: F821 - FIRES: 2nd resolve
    return a, b


def bad_pickle_fanout(store, obj, workers):
    p = store.proxy(obj, evict=True)
    for w in workers:
        w.send(pickle.dumps(p))               # FIRES: fan-out pickle in loop
    return None


def ok_single(store, obj):
    p = store.proxy(obj, evict=True)
    return extract(p)                         # noqa: F821


def ok_allowlisted(store, obj):
    p = store.proxy(obj, evict=True)
    a = extract(p)                            # noqa: F821
    b = extract(p)  # lint: evict-ok          # noqa: F821
    return a, b
