"""R2 fixture: a borrowed channel view escaping a scope that drops
references.  Linted by tests, never imported."""


def bad_escape(arena, store, slot, gen, key):
    view = arena.read(slot, gen)
    store.decref(key)
    return view                               # FIRES: un-materialized escape


def ok_materialized(arena, store, slot, gen, key):
    view = arena.read(slot, gen)
    obj = materialize(view)                   # noqa: F821 - AST fixture
    store.decref(key)
    return obj


def ok_allowlisted(arena, store, slot, gen, key):
    view = arena.read(slot, gen)
    store.decref(key)
    return view  # lint: borrow-ok


def ok_no_drop(arena, slot, gen):
    view = arena.read(slot, gen)
    return view                               # no drops in scope: fine
