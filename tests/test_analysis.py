"""The two-headed correctness tool: proxylint rule fixtures (each R1-R7
fires; each allowlist suppresses) and the runtime sanitizer's four seeded
defect classes (use-after-free view, refcount leak, double-decref,
poisoned stale read), each detected with its named diagnostic."""
import multiprocessing as mp
import os
from pathlib import Path

import pytest

from repro.analysis import SanitizerError, SanitizerWarning
from repro.analysis.lint import lint_file, lint_paths, lint_source, main
from repro.analysis.sanitize import RefLedger, check_view, looks_poisoned
from repro.core import deserialize, serialize
from repro.core.arena import ArenaPool
from repro.core.connectors.memory import LocalMemoryConnector
from repro.core.store import Store

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
REPO = Path(__file__).resolve().parents[1]


def _lint_fixture(name: str, as_path: str | None = None):
    src = (FIXTURES / name).read_text(encoding="utf-8")
    return lint_source(src, as_path or str(FIXTURES / name))


def _assert_allowlist_suppressed(findings, name: str, tag: str) -> None:
    """No finding may land on a line carrying its allowlist tag."""
    lines = (FIXTURES / name).read_text(encoding="utf-8").splitlines()
    tagged = {i + 1 for i, ln in enumerate(lines) if f"lint: {tag}" in ln}
    assert not {f.line for f in findings} & tagged


# ---------------------------------------------------------------------------
# Head 1: proxylint rule fixtures
# ---------------------------------------------------------------------------
def test_r1_wallclock_fires_and_allowlists():
    findings = _lint_fixture("r1_wallclock.py")
    assert [f.rule for f in findings] == ["R1"] * 3
    assert any("monotonic" in f.message for f in findings)
    _assert_allowlist_suppressed(findings, "r1_wallclock.py", "wallclock-ok")


def test_r2_borrowed_view_escape():
    findings = _lint_fixture("r2_borrow.py")
    assert [f.rule for f in findings] == ["R2"]
    assert "materialize" in findings[0].message
    _assert_allowlist_suppressed(findings, "r2_borrow.py", "borrow-ok")


def test_r3_ephemeral_multi_resolve_and_fanout():
    findings = _lint_fixture("r3_evict.py")
    assert sorted(f.rule for f in findings) == ["R3", "R3"]
    msgs = " ".join(f.message for f in findings)
    assert "resolved more than once" in msgs and "pickled inside" in msgs
    _assert_allowlist_suppressed(findings, "r3_evict.py", "evict-ok")


def test_r4_bare_assert_is_core_scoped():
    # same source, linted under a core path vs anywhere else
    core = _lint_fixture("r4_asserts.py", "src/repro/core/fixture.py")
    assert [f.rule for f in core] == ["R4"]
    assert "python -O" in core[0].message
    _assert_allowlist_suppressed(core, "r4_asserts.py", "assert-ok")
    assert _lint_fixture("r4_asserts.py", "src/repro/train/fixture.py") == []


def test_r5_blocking_in_async_is_file_scoped():
    findings = _lint_fixture("r5_async.py", "src/repro/core/kv_tcp.py")
    assert [f.rule for f in findings] == ["R5"] * 3
    blocked = " ".join(f.message for f in findings)
    assert "time.sleep" in blocked and "open()" in blocked \
        and ".sendall()" in blocked
    _assert_allowlist_suppressed(findings, "r5_async.py", "blocking-ok")
    # the same source outside the event-loop modules is not flagged
    assert _lint_fixture("r5_async.py", "src/repro/train/worker.py") == []


def test_r6_nonidempotent_retry():
    findings = _lint_fixture("r6_retry.py")
    assert [f.rule for f in findings] == ["R6"] * 4
    msgs = " ".join(f.message for f in findings)
    assert "'decref'" in msgs and "'put2'" in msgs and "'s_append'" in msgs
    _assert_allowlist_suppressed(findings, "r6_retry.py", "retry-ok")


def test_r7_unclosed_stream_consumer():
    findings = _lint_fixture("r7_stream.py")
    assert [f.rule for f in findings] == ["R7"] * 3
    msgs = " ".join(f.message for f in findings)
    assert "'stream'" in msgs and "no handle to close()" in msgs \
        and "'tap'" in msgs
    _assert_allowlist_suppressed(findings, "r7_stream.py", "stream-ok")


def test_lint_cli_and_syntax_error(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\n\ndef f(t):\n    return time.time() - t\n")
    assert main([str(bad)]) == 1
    assert "R1" in capsys.readouterr().out
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean), "-q"]) == 0
    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n")
    assert lint_file(broken)[0].rule == "E0"


def test_src_tree_is_lint_clean():
    """The acceptance gate CI enforces: zero findings on the PR's tree."""
    assert lint_paths([str(REPO / "src")]) == []


# ---------------------------------------------------------------------------
# Head 2: the runtime sanitizer's seeded defect classes
# ---------------------------------------------------------------------------
def test_use_after_free_view_names_borrow_site(tmp_path):
    pool = ArenaPool(str(tmp_path / "shm"), sanitize=True)
    try:
        name, slot, gen = pool.put([b"x" * 2048], 2048)
        arena = pool.attach(name)
        view = arena.read(slot, gen)
        with pytest.raises(SanitizerError, match="use-after-free-view") as ei:
            arena.free(slot, gen)
        assert ei.value.diagnostic == "use-after-free-view"
        assert "test_analysis" in str(ei.value)   # the borrow site is named
        del view                                  # dropping it unblocks
        assert arena.free(slot, gen)
    finally:
        pool.close()


def test_poisoned_stale_read(tmp_path):
    pool = ArenaPool(str(tmp_path / "shm"), sanitize=True)
    try:
        payload = serialize({"v": list(range(64))})
        nbytes = sum(len(bytes(s)) for s in payload)
        name, slot, gen = pool.put(payload, nbytes)
        arena = pool.attach(name)
        view = arena.read(slot, gen)
        stale = view[:nbytes]                 # a slice survives the free
        del view
        assert arena.free(slot, gen)          # poisons the chunk 0xDE
        assert looks_poisoned(stale)
        with pytest.raises(SanitizerError, match="poisoned-read") as ei:
            check_view(stale)
        assert ei.value.diagnostic == "poisoned-read"
        # the deserializer recognizes the poison pattern too
        with pytest.raises(SanitizerError, match="poisoned-read"):
            deserialize(bytes(stale))
    finally:
        pool.close()


def test_quarantine_delays_chunk_reuse(tmp_path):
    """A freed chunk must not be recycled by the very next put: reuse only
    after a strictly younger free."""
    pool = ArenaPool(str(tmp_path / "shm"), sanitize=True)
    try:
        name, slot, gen = pool.put([b"a" * 1024], 1024)
        arena = pool.attach(name)
        view = arena.read(slot, gen)
        off1 = None
        for s, g, size in arena.live_slots():
            if s == slot:
                off1 = arena._entry(s)[6]
        del view
        pool.free(name, slot, gen)
        n2, s2, g2 = pool.put([b"b" * 1024], 1024)
        off2 = pool.attach(n2)._entry(s2)[6]
        assert (n2, off2) != (name, off1)     # quarantined, not recycled
    finally:
        pool.close()


def test_double_decref_and_use_after_evict():
    store = Store("san-ledger", LocalMemoryConnector(), sanitize=True)
    key = store.put({"a": 1})
    store.incref(key)
    assert store.decref(key) == 0             # legal: count hits zero
    with pytest.raises(SanitizerError, match="double-decref") as ei:
        store.decref(key)                     # raised BEFORE the channel op
    assert ei.value.diagnostic == "double-decref"
    assert "test_analysis" in str(ei.value)   # acquire site backtrace
    with pytest.raises(SanitizerError, match="use-after-evict") as ei:
        store.incref(key)                     # the key is gone
    assert ei.value.diagnostic == "use-after-evict"
    store.close()


def test_refcount_leak_reported_at_close():
    store = Store("san-leak", LocalMemoryConnector(), sanitize=True)
    key = store.put([1, 2, 3])
    store.incref(key)                         # never released
    with pytest.warns(SanitizerWarning, match="refcount-leak") as rec:
        store.close()
    text = str(rec[0].message)
    assert "1 leaked reference" in text and "first acquired at" in text


def test_balanced_lifecycle_is_quiet():
    import warnings

    store = Store("san-clean", LocalMemoryConnector(), sanitize=True)
    key = store.put("payload")
    store.incref(key)
    store.decref(key)
    with warnings.catch_warnings():
        warnings.simplefilter("error", SanitizerWarning)
        store.close()                         # no leak candidates: silent


def test_transfer_budget_allows_local_roundtrip():
    """A pickle-incref (transfer) raises the local release budget, so a
    same-process pickle/unpickle/resolve cycle is not a double-decref."""
    ledger = RefLedger("t")
    ledger.incref("k")                        # proxy creation
    ledger.incref("k", transfer=True)         # pickled sibling's ref
    ledger.decref("k")                        # sibling resolved locally
    ledger.decref("k")                        # original resolved
    with pytest.raises(SanitizerError, match="double-decref"):
        ledger.decref("k")                    # beyond the budget


def _orphan_child(registry_dir: str) -> None:
    pool = ArenaPool(registry_dir)
    pool.put([b"orphan-payload" * 64], 14 * 64)
    os._exit(0)                               # die without cleanup


def test_sweep_reports_orphaned_slots(tmp_path):
    """Satellite: sweep() itemizes WHAT leaked (arena, slot, owner pid),
    not just a count — with and without reclaiming."""
    registry = str(tmp_path / "shm")
    ctx = mp.get_context("spawn")
    child = ctx.Process(target=_orphan_child, args=(registry,))
    child.start()
    child.join(timeout=30)
    assert child.exitcode == 0

    pool = ArenaPool(registry)
    try:
        pool.sweep()                          # report-only pass
        report = pool.last_sweep_report
        assert len(report) == 1
        rec = report[0]
        assert rec["owner_pid"] == child.pid
        assert rec["size"] == 14 * 64
        assert rec["reclaimed"] is False
        pool.sweep(clear=True)                # reclaim pass
        assert pool.last_sweep_report[0]["reclaimed"] is True
        pool.sweep(clear=True)
        assert pool.last_sweep_report == []   # nothing left to report
    finally:
        pool.close()


def test_forced_retry_on_nonidempotent_op(tmp_path, monkeypatch):
    """The R6 rule's runtime twin: KVClient.request(retry=True) on a
    non-idempotent op is a hard error under the sanitizer."""
    import signal

    from repro.core.kv_tcp import KVClient, spawn_server

    monkeypatch.setenv("REPRO_SANITIZE", "1")
    host, port, pid = spawn_server(ready_file=str(tmp_path / "kv.ready"))
    client = KVClient(host, port)
    try:
        with pytest.raises(SanitizerError, match="non-idempotent-retry") as ei:
            client.request({"op": "decref", "key": "k"}, retry=True)
        assert ei.value.diagnostic == "non-idempotent-retry"
        # idempotent ops still retry transparently
        assert client.request({"op": "ping"}, retry=True)["data"] == "pong"
        client.shutdown_server()
    finally:
        client.close()
        try:
            os.kill(pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass
