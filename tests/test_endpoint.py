"""Relay + PS-endpoint peering (paper §4.2.2, Fig 4)."""
import os
import pickle

import pytest

from repro.core import Store
from repro.core.connectors import EndpointConnector
from repro.core.deploy import start_endpoint, start_relay
from repro.core.store import unregister_store


@pytest.fixture(scope="module")
def fabric(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("fabric"))
    relay = start_relay(d)
    ep_a = start_endpoint(d, relay.address, name="a")
    ep_b = start_endpoint(d, relay.address, name="b")
    yield relay, ep_a, ep_b
    for h in (ep_a, ep_b, relay):
        h.stop()


def test_local_ops(fabric):
    _, ep_a, _ = fabric
    c = EndpointConnector(address=ep_a.address)
    key = c.put(b"local-object")
    assert c.exists(key)
    assert c.get(key) == b"local-object"
    c.evict(key)
    assert not c.exists(key)


def test_peer_forwarding(fabric):
    _, ep_a, ep_b = fabric
    ca = EndpointConnector(address=ep_a.address)
    cb = EndpointConnector(address=ep_b.address)
    key = ca.put(b"on-A" * 1000)
    # request to B for a key owned by A -> relay introduction -> peer channel
    assert cb.get(key) == b"on-A" * 1000
    assert cb.exists(key)
    cb.evict(key)
    assert not ca.exists(key)


def test_batch_ops_local_and_remote(fabric):
    """put_batch is one mput2 exchange; get_batch groups keys by owning
    endpoint — remote groups are forwarded over the peer channel."""
    _, ep_a, ep_b = fabric
    ca = EndpointConnector(address=ep_a.address)
    cb = EndpointConnector(address=ep_b.address)
    blobs = [bytes([i]) * (100 * i + 1) for i in range(5)]
    keys_a = ca.put_batch(blobs)
    assert ca.get_batch(keys_a) == blobs
    # B resolves A's objects (one forwarded mget) plus one of its own
    kb = cb.put(b"on-b")
    mixed = list(keys_a) + [kb]
    got = cb.get_batch(mixed)
    assert got[:5] == blobs
    assert got[5] == b"on-b"
    assert cb.exists_batch(mixed) == [True] * 6
    cb.evict_batch(mixed)
    assert ca.exists_batch(mixed) == [False] * 6


def test_unknown_endpoint_errors(fabric):
    _, ep_a, _ = fabric
    ca = EndpointConnector(address=ep_a.address)
    with pytest.raises(ConnectionError):
        ca.get(("ep", "object", "no-such-endpoint-uuid"))


def test_cross_site_proxy_resolution(fabric, monkeypatch):
    """A proxy created at site A resolves at site B via B's local endpoint."""
    _, ep_a, ep_b = fabric
    monkeypatch.setenv("PSJ_ENDPOINT", ep_a.address)
    store = Store("xsite", EndpointConnector())
    p = store.proxy({"payload": list(range(50))})
    wire = pickle.dumps(p)
    # consumer process at site B
    unregister_store("xsite")
    monkeypatch.setenv("PSJ_ENDPOINT", ep_b.address)
    p2 = pickle.loads(wire)
    assert p2["payload"][-1] == 49
    unregister_store("xsite")
