"""Store semantics: caching, registry, proxies, async resolve (paper §3.5)."""
import os
import pickle

import numpy as np
import pytest

from repro.core import (Store, get_factory, get_or_create_store, get_store,
                        is_resolved, maybe_proxy, register_store,
                        resolve_async, unregister_store)
from repro.core.connectors import FileConnector, LocalMemoryConnector


def make_store(tmp_path, name="s"):
    return Store(name, FileConnector(str(tmp_path / name)))


def test_put_get_evict_objects(tmp_path):
    s = make_store(tmp_path)
    key = s.put({"x": np.arange(4), "y": (1, 2)})
    out = s.get(key)
    np.testing.assert_array_equal(out["x"], np.arange(4))
    assert out["y"] == (1, 2)
    s.evict(key)
    assert s.get(key) is None


def test_cache_after_deserialization(tmp_path):
    s = make_store(tmp_path)
    key = s.put(np.zeros(1000))
    a = s.get(key)
    b = s.get(key)
    assert a is b                       # cached object identity
    assert s.cache.hits >= 1
    s.connector.evict(key)              # bypass store: connector-level evict
    assert s.get(key) is not None       # cache still serves it
    s.evict(key)                        # store evict drops cache too
    assert s.get(key) is None


def test_registry_and_factory_rematerialization(tmp_path):
    s = Store("remat-store", FileConnector(str(tmp_path / "d")))
    p = s.proxy({"v": 7})
    blob = pickle.dumps(p)
    # simulate a remote process: no store registered under that name
    unregister_store("remat-store")
    assert get_store("remat-store") is None
    p2 = pickle.loads(blob)
    assert p2["v"] == 7                      # factory rebuilt the store
    assert get_store("remat-store") is not None  # and registered it


def test_duplicate_registration_rejected(tmp_path):
    s1 = Store("dup", LocalMemoryConnector())
    with pytest.raises(ValueError):
        Store("dup", LocalMemoryConnector())
    unregister_store("dup")


def test_proxy_evict_flag(tmp_path):
    s = make_store(tmp_path)
    p = s.proxy([1, 2, 3], evict=True)
    key = get_factory(p).key
    assert s.exists(key)
    assert p[0] == 1
    assert not s.connector.exists(key)


def test_proxy_batch(tmp_path):
    s = make_store(tmp_path)
    proxies = s.proxy_batch([{"i": i} for i in range(5)])
    assert [p["i"] for p in proxies] == list(range(5))


def test_resolve_async(tmp_path):
    s = make_store(tmp_path)
    p = pickle.loads(pickle.dumps(s.proxy(np.arange(10))))
    resolve_async(p)
    np.testing.assert_array_equal(np.asarray(p), np.arange(10))


def test_missing_key_raises_lookup(tmp_path):
    s = make_store(tmp_path)
    p = s.proxy_from_key(("file", s.connector.store_dir, "deadbeef"))
    from repro.core import ProxyResolveError

    with pytest.raises(ProxyResolveError, match="not found"):
        _ = len(p)


def test_get_batch_mixes_cache_and_connector(tmp_path):
    s = make_store(tmp_path)
    objs = [{"i": i, "a": np.full(100, i)} for i in range(6)]
    keys = [s.put(o) for o in objs]
    warm = s.get(keys[2])                    # prime one cache entry
    out = s.get_batch(keys + [("file", s.connector.store_dir, "nope")])
    assert out[2] is warm                    # cache hit preserved identity
    for i, o in enumerate(out[:6]):
        assert o["i"] == i
        np.testing.assert_array_equal(o["a"], np.full(100, i))
    assert out[6] is None                    # missing key -> default
    # a second batch is served fully from cache
    hits_before = s.cache.hits
    s.get_batch(keys)
    assert s.cache.hits == hits_before + 6


def test_store_async_put_get(tmp_path):
    s = make_store(tmp_path)
    futs = [s.put_async({"n": i}) for i in range(4)]
    keys = [f.result(10) for f in futs]
    gets = [s.get_async(k) for k in keys]
    assert [g.result(10)["n"] for g in gets] == list(range(4))


def test_resolve_async_batch_groups_by_store(tmp_path):
    """resolve_async on a proxy batch pre-fetches every target with one
    batched exchange per store; consumption touches warm futures only."""
    s = make_store(tmp_path)
    proxies = s.proxy_batch([{"v": i} for i in range(8)])
    wire = pickle.loads(pickle.dumps(proxies))     # consumer-side copies
    resolve_async(wire)
    assert [p["v"] for p in wire] == list(range(8))


def test_resolve_async_batch_missing_key_raises(tmp_path):
    s = make_store(tmp_path)
    good = s.proxy({"ok": 1})
    bad = s.proxy_from_key(("file", s.connector.store_dir, "missing"))
    resolve_async([good, bad])
    assert good["ok"] == 1
    from repro.core import ProxyResolveError

    with pytest.raises(ProxyResolveError, match="not found"):
        _ = len(bad)


def test_store_stats(tmp_path):
    from repro.core.connectors import KVServerConnector
    from repro.core.deploy import start_kvserver

    h = start_kvserver(str(tmp_path))
    s = Store("stats-store", KVServerConnector(h.host, h.port))
    try:
        key = s.put({"x": 1})
        s.get(key)          # miss (fills cache)
        s.get(key)          # hit
        stats = s.stats()
        assert stats["cache_hits"] >= 1
        assert stats["cache_misses"] >= 1
        assert stats["cache_len"] == 1
        assert stats["connector"]["n_objects"] == 1
        assert stats["connector"]["n_ops"] >= 2
    finally:
        s.close()
        h.stop()


def test_maybe_proxy_threshold(tmp_path):
    s = make_store(tmp_path)
    small = maybe_proxy(s, [1, 2], threshold_bytes=10_000)
    assert small == [1, 2] and not hasattr(small, "_proxy_factory")
    rng = np.random.default_rng(0)
    big = maybe_proxy(s, rng.standard_normal(10_000), threshold_bytes=10_000)
    assert not is_resolved(big)
    assert big.shape == (10_000,)
