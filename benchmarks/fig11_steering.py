"""Fig 11 analog: worker utilization vs scale in an ML-in-the-loop workflow.

Simulation tasks (fixed compute) return bulky results through the task
server; as worker count grows the server data path saturates and workers
starve — unless results travel by proxy.  Utilization = ideal wall time /
measured wall time, the paper's Fig 11 quantity.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.util import emit, payload, tmpdir
from repro.core import Store
from repro.core.connectors import SharedMemoryConnector
from repro.federated.steer import SteerConfig, Steering

TASK_S = 0.05          # per-task "simulation" compute
RESULT_BYTES = 4_000_000
N_TASKS = 24


def run() -> None:
    d = tmpdir("fig11")
    result = payload(RESULT_BYTES)

    def sim(_x):
        time.sleep(TASK_S)
        return result

    for n_workers in (2, 4, 8):
        ideal = N_TASKS * TASK_S / n_workers
        store = Store(f"fig11-{n_workers}",
                      SharedMemoryConnector(os.path.join(d, f"s{n_workers}")))
        s1 = Steering(SteerConfig(n_workers=n_workers,
                                  proxy_threshold=100_000), store)
        r1 = s1.run(sim, lambda i: np.int32(i), N_TASKS,
                    n_outstanding=2 * n_workers)
        s1.close()
        s2 = Steering(SteerConfig(n_workers=n_workers,
                                  proxy_threshold=None), None)
        r2 = s2.run(sim, lambda i: np.int32(i), N_TASKS,
                    n_outstanding=2 * n_workers)
        s2.close()
        u1, u2 = ideal / r1["wall_s"], ideal / r2["wall_s"]
        emit(f"fig11.util.proxy.w{n_workers}", r1["wall_s"] * 1e6,
             f"utilization={u1:.2f}")
        emit(f"fig11.util.baseline.w{n_workers}", r2["wall_s"] * 1e6,
             f"utilization={u2:.2f}")


if __name__ == "__main__":
    run()
