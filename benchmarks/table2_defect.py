"""Table 2 analog: real-time defect analysis round-trip.

A JAX conv "segmentation model" scores 1 MB images dispatched through the
FaaS executor.  Rows: baseline (image by value), inputs proxied, and
inputs+outputs proxied — the paper reports 32.1%/36.6% improvements for
FileStore; the relative ordering is the reproduced claim.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.util import emit, time_call, tmpdir
from repro.core import Store
from repro.core.connectors import FileConnector
from repro.core.proxy import extract, is_proxy
from repro.core.store import get_or_create_store
from repro.federated.faas import CloudModel, FaasExecutor

IMG = (512, 512)  # 1 MB float32


def segment(image, out_store_cfg_blob=None):
    """Mock ML inference: separable blur + threshold (pure numpy on the
    worker; stands in for the paper's GPU segmentation model)."""
    if is_proxy(image):
        image = extract(image)
    x = np.asarray(image)
    k = np.ones(8) / 8
    x = np.apply_along_axis(lambda r: np.convolve(r, k, "same"), 1, x)
    mask = (x > x.mean()).astype(np.uint8)
    if out_store_cfg_blob is not None:
        import pickle

        store = get_or_create_store(pickle.loads(out_store_cfg_blob))
        return store.proxy(mask)   # output by reference too
    return mask


def run() -> None:
    d = tmpdir("table2")
    ex = FaasExecutor(n_workers=1,
                  cloud=CloudModel(latency_s=0.02, bandwidth_bps=10e6))
    store = Store("table2", FileConnector(os.path.join(d, "store")))
    rng = np.random.default_rng(0)
    image = rng.standard_normal(IMG).astype(np.float32)

    t_base = time_call(lambda: np.asarray(
        ex.submit(segment, image).result()).sum(), reps=3)
    emit("table2.baseline", t_base * 1e6, "value-in/value-out")

    t_in = time_call(lambda: np.asarray(
        ex.submit(segment, store.proxy(image)).result()).sum(), reps=3)
    emit("table2.proxy-inputs", t_in * 1e6,
         f"improvement={100*(t_base-t_in)/t_base:.1f}%")

    import pickle

    cfg_blob = pickle.dumps(store.config())
    t_io = time_call(lambda: np.asarray(extract(
        ex.submit(segment, store.proxy(image),
                  cfg_blob).result())).sum(), reps=3)
    emit("table2.proxy-inputs-outputs", t_io * 1e6,
         f"improvement={100*(t_base-t_io)/t_base:.1f}%")
    ex.shutdown()


if __name__ == "__main__":
    run()
