"""Fig 16 (repo extension): durability — chain-replication egress,
zero-loss failover, and at-least-once stream delivery.

Three measurements over the 4-shard / replication-2 fabric (Unix-domain
shards, the same-host deployment CI can exercise):

* ``fig16.egress.{chain,legacy}.*`` — client put egress under
  server-side chain replication vs the legacy client fanout.  The same
  batch of blobs is put through both modes and the fabric's summed
  client TX byte counters are compared: the chain path uploads ONE copy
  (the head forwards to its ring successors shard-to-shard), so the
  recorded ``egress_ratio_chain_vs_legacy`` lands near 1/R — the
  tentpole claim, gated at ≤ 0.75 for R=2.

* ``fig16.durability.kill1of4`` — SIGKILL one shard under a live
  chain-replicated write workload and verify the zero-lost-committed-
  puts guarantee: every put acked before or after the kill must resolve
  via failover reads (``lost_puts`` is recorded and must be 0).  Replica
  writes that failed mid-chain surface in ``n_repl_errors`` and queue
  for repair instead of being dropped silently.

* ``fig16.stream.failover`` — SIGKILL the home shard of a topic with an
  active consumer group mid-stream.  The group must resume from the
  replicated cursor with every committed event delivered at least once
  (``skipped_seqs`` must be 0); duplicates are the permitted cost and
  are recorded as ``redelivery_ratio`` (total deliveries / unique
  committed events, gated ≤ 1.5).  A poison event requeued past
  ``max_deliveries`` must land in ``<topic>.dlq`` (``dlq_count``).

``run(micro=True)`` is the perf-gate tier: fewer/smaller blobs and a
shorter stream, same invariants.
"""
from __future__ import annotations

import time

from benchmarks.util import emit, fmt_bytes, record, time_call, tmpdir
from repro.core.deploy import start_kvserver
from repro.core.fabric import ShardedConnector
from repro.core.kv_tcp import dlq_topic
from repro.distributed.chaos import (crash_during_cursor_replication,
                                     kill_shard)

SIZE = 262_144
N_SHARDS = 4


def _spawn(d: str, tag: str, chain: bool = True,
           op_timeout: float = 10.0):
    handles = [start_kvserver(d, name=f"{tag}{i}", uds=True)
               for i in range(N_SHARDS)]
    fab = ShardedConnector([h.host for h in handles], replication=2,
                           quorum=True, op_timeout=op_timeout, chain=chain)
    return handles, fab


def _egress_row(micro: bool) -> dict:
    """Same blob batch through chain vs legacy puts; compare client TX."""
    batch = 16 if micro else 64
    blobs = [bytes([i % 251]) * SIZE for i in range(batch)]
    nbytes = sum(len(b) for b in blobs)
    out: dict = {}
    tx: dict[str, int] = {}
    for mode, chain in (("chain", True), ("legacy", False)):
        d = tmpdir(f"fig16-egress-{mode}")
        handles, fab = _spawn(d, "eg", chain=chain)
        try:
            fab.put_batch(blobs)                     # warm: conns + ring
            base = fab.stats()["fabric"]["client_tx_bytes"]
            t = time_call(lambda: fab.put_batch(blobs), reps=1, warmup=0,
                          inner=1)
            tx[mode] = fab.stats()["fabric"]["client_tx_bytes"] - base
            emit(f"fig16.egress.{mode}.{fmt_bytes(SIZE)}", t * 1e6,
                 f"{tx[mode] / 1e6:.1f}MB client tx for "
                 f"{nbytes / 1e6:.1f}MB payload r{fab.replication}",
                 mb_per_s=nbytes / t / 1e6)
            out[f"put_mb_per_s_{mode}"] = round(nbytes / t / 1e6, 1)
            out[f"client_tx_mb_{mode}"] = round(tx[mode] / 1e6, 2)
        finally:
            fab.close()
            for h in handles:
                h.stop()
    out["egress_ratio_chain_vs_legacy"] = round(tx["chain"] / tx["legacy"],
                                                3)
    return out


def _durability_row(micro: bool) -> dict:
    """Kill 1 of 4 shards under chain-replicated writes: zero committed
    puts lost, failed replica hops surfaced + queued for repair."""
    d = tmpdir("fig16-durability")
    handles, fab = _spawn(d, "dur", op_timeout=5.0)
    try:
        n = 32 if micro else 128
        keys = fab.put_batch([b"committed-pre-kill" * 64
                              for _ in range(n)])
        kill_shard(handles[0])
        # writes keep committing through the failure window; unacked
        # attempts may fail, acked ones must survive
        acked: list = []
        deadline = time.monotonic() + 30.0
        while len(acked) < n and time.monotonic() < deadline:
            try:
                acked.append(fab.put(b"mid-kill-write" * 64))
            except (ConnectionError, TimeoutError, OSError):
                pass
        lost = sum(b is None for b in fab.get_batch(keys + acked))
        st = fab.stats()["fabric"]
        emit("fig16.durability.kill1of4", 0.0,
             f"{lost} lost of {len(keys) + len(acked)} committed, "
             f"{st['n_repl_errors']} repl errors, "
             f"{st['n_repairs_pending']} queued repairs")
        return {"lost_puts": lost,
                "committed_puts": len(keys) + len(acked),
                "n_repl_errors": st["n_repl_errors"],
                "n_repairs_pending": st["n_repairs_pending"],
                "n_hint_shards_pending": st["n_hint_shards_pending"]}
    finally:
        fab.close()
        for h in handles:
            h.stop()


def _retrying(fn, deadline_s: float = 30.0):
    deadline = time.monotonic() + deadline_s
    while True:
        try:
            return fn()
        except (ConnectionError, TimeoutError, OSError):
            if time.monotonic() > deadline:
                raise
            time.sleep(0.05)


def _stream_row(micro: bool) -> dict:
    """Kill the topic home mid-stream: at-least-once resume from the
    replicated cursor, poison event dead-lettered."""
    d = tmpdir("fig16-stream")
    handles, fab = _spawn(d, "str", op_timeout=5.0)
    try:
        n = 24 if micro else 96
        poison_at = n // 2
        fab.stream_subscribe("events", "workers")
        fab.stream_subscribe(dlq_topic("events"), "audit")
        fab.stream_limit("events", None, max_deliveries=2)
        committed: set[int] = set()
        for i in range(n // 2):
            meta = ({"i": i, "poison": True} if i == poison_at
                    else {"i": i})
            committed.add(fab.stream_append("events", f"e{i}".encode(),
                                            meta=meta))
        home = fab._stream_home["events"]
        victim = next(h for h in handles if h.host == home)
        sched = crash_during_cursor_replication(victim, delay_s=0.02)
        for i in range(n // 2, n):
            meta = ({"i": i, "poison": True} if i == poison_at
                    else {"i": i})
            committed.add(_retrying(lambda i=i, meta=meta:
                                    fab.stream_append(
                                        "events", f"e{i}".encode(),
                                        meta=meta)))
        sched.join(10.0)
        t0 = time.perf_counter()
        seen: set[int] = set()
        deliveries = 0
        poison_dead = False
        # drain until every committed seq was delivered AND the poison
        # event actually dead-lettered: a requeue that moves the event to
        # the DLQ returns 0 (nothing went back in the queue) — until then
        # the poison is still pending redelivery and the loop must keep
        # taking or it never reaches max_deliveries
        while not (committed <= seen and poison_dead):
            if time.perf_counter() - t0 > 60.0:
                break
            ev = _retrying(lambda: fab.stream_take("events", "workers",
                                                   timeout=10.0))
            deliveries += 1
            seen.add(ev.seq)
            if ev.meta.get("poison"):
                back = _retrying(lambda: fab.stream_requeue(
                    "events", "workers", [ev.seq], reason="poison"))
                if not back:
                    poison_dead = True
            else:
                _retrying(lambda: fab.stream_ack("events", "workers",
                                                 [ev.seq]))
        skipped = len(committed - seen)
        dlq = 0
        try:
            dev = _retrying(lambda: fab.stream_take(
                dlq_topic("events"), "audit", timeout=15.0),
                deadline_s=30.0)
            dlq = int(bool(dev.meta.get("dlq")))
        except (ConnectionError, TimeoutError, OSError):
            pass
        ratio = deliveries / max(1, len(committed))
        emit("fig16.stream.failover", 0.0,
             f"{len(committed)} committed, {skipped} skipped, "
             f"redelivery x{ratio:.2f}, {dlq} dead-lettered, "
             f"{fab.n_failovers} failovers")
        return {"stream_committed": len(committed),
                "skipped_seqs": skipped,
                "redelivery_ratio": round(ratio, 3),
                "dlq_count": dlq,
                "n_failovers": fab.n_failovers}
    finally:
        fab.close()
        for h in handles:
            h.stop()


def run(micro: bool = False) -> None:
    results: dict = {}
    results.update(_egress_row(micro))
    results.update(_durability_row(micro))
    results.update(_stream_row(micro))
    record("durability", results)


if __name__ == "__main__":
    run()
