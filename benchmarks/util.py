"""Shared benchmark helpers: timing + CSV emission + fixtures."""
from __future__ import annotations

import statistics
import tempfile
import time
from typing import Callable

import numpy as np

ROWS: list[dict] = []
RESULTS: dict[str, dict] = {}


def emit(name: str, us_per_call: float, derived: str = "",
         mb_per_s: float | None = None,
         req_per_s: float | None = None) -> None:
    """Record one benchmark row.

    Rows are structured (numeric ``us_per_call`` and optional numeric
    ``mb_per_s`` / ``req_per_s`` — never strings like ``"202MB/s"``) so the
    CI perf gate and trend plots can parse ``BENCH_*.json`` without
    re-lexing; ``derived`` stays free-form for human context.  The CSV
    print is unchanged.
    """
    row: dict = {"name": name, "us_per_call": round(float(us_per_call), 1),
                 "derived": derived}
    if mb_per_s is not None:
        row["mb_per_s"] = round(float(mb_per_s), 1)
    if req_per_s is not None:
        row["req_per_s"] = round(float(req_per_s), 2)
    ROWS.append(row)
    print(f"{name},{row['us_per_call']},{derived}", flush=True)


def record(tag: str, data: dict) -> None:
    """Stash structured results for ``benchmarks.run`` to dump into
    ``BENCH_<tag>.json`` (the per-PR perf trajectory record)."""
    RESULTS.setdefault(tag, {}).update(data)


def time_call(fn: Callable, *, reps: int = 3, warmup: int = 1,
              inner: int = 1) -> float:
    """Median wall seconds per call.

    ``inner`` > 1 times a back-to-back loop of calls per rep and divides:
    this container's scheduler adds multi-ms spikes to individual calls,
    so amortizing a few calls per sample estimates steady-state per-call
    cost far more stably than single-shot medians.
    """
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        times.append((time.perf_counter() - t0) / inner)
    return statistics.median(times)


def payload(n_bytes: int, seed: int = 0) -> np.ndarray:
    """Incompressible float32 payload of ~n_bytes."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal(max(n_bytes // 4, 1)).astype(np.float32)


def tmpdir(prefix: str) -> str:
    return tempfile.mkdtemp(prefix=f"psj-bench-{prefix}-")


def fmt_bytes(n: int) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024:
            return f"{n:.0f}{unit}"
        n /= 1024
    return f"{n:.0f}TB"
