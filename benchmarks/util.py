"""Shared benchmark helpers: timing + CSV emission + fixtures."""
from __future__ import annotations

import statistics
import tempfile
import time
from typing import Callable

import numpy as np

ROWS: list[str] = []
RESULTS: dict[str, dict] = {}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def record(tag: str, data: dict) -> None:
    """Stash structured results for ``benchmarks.run`` to dump into
    ``BENCH_<tag>.json`` (the per-PR perf trajectory record)."""
    RESULTS.setdefault(tag, {}).update(data)


def time_call(fn: Callable, *, reps: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def payload(n_bytes: int, seed: int = 0) -> np.ndarray:
    """Incompressible float32 payload of ~n_bytes."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal(max(n_bytes // 4, 1)).astype(np.float32)


def tmpdir(prefix: str) -> str:
    return tempfile.mkdtemp(prefix=f"psj-bench-{prefix}-")


def fmt_bytes(n: int) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024:
            return f"{n:.0f}{unit}"
        n /= 1024
    return f"{n:.0f}TB"
