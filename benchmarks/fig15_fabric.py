"""Fig 15 (repo extension): sharded KV fabric — scaling + recovery.

Two measurements over the consistent-hash fabric
(:class:`repro.core.fabric.ShardedConnector`, replication 2, quorum
acks, Unix-domain shards — the same-host deployment CI can exercise):

* ``fig15.agg.{n}shard.*`` — aggregate put+get throughput vs shard
  count.  One round trip = ``put_batch`` of B pre-serialized 1 MB frames
  + ``get_batch`` + ``evict_batch``, driven through the fabric's
  :meth:`ShardedConnector.pipeline` (every per-shard ``mput2``/``mget2``/
  ``mevict`` exchange is submitted before any ack is awaited; FIFO
  connection order keeps it correct), so all shards stay busy end to end
  instead of idling between lock-stepped phases.

  Two throughput numbers are recorded per row, with nothing hidden:

  - **served** (the emitted ``mb_per_s``, the row's headline): bytes the
    shard fleet actually moves — replicated put ingress plus get egress,
    ``nbytes * (replication + 1) / t``.  This is the standard
    aggregate-bandwidth accounting for replicated/parallel stores (every
    server byte counted once), and it reduces EXACTLY to the fig6
    convention (``nbytes * 2 / t``) at replication 1, so the 1-shard row
    and the ``fig6.kvserver`` baseline are directly comparable.
  - **goodput** (``goodput_mb_per_s`` in BENCH_fabric.json): client-
    visible application bytes only, ``nbytes * 2 / t`` — replication
    overhead *paid*, not credited.

  The acceptance bar — 4-shard aggregate ≥ 2x the single-server
  ``fig6.kvserver.977KB`` baseline — is checked against the served
  number and recorded in the JSON (baseline, bar, and ratio), along with
  the goodput ratio for full transparency.

  Timing is min-of-samples, not median: this container is a single-vCPU
  VM with multi-ms host-steal spikes (client + N shard processes share
  ONE core, so these rows UNDERSTATE real multi-core scaling to begin
  with); the minimum is the least-interference estimate of what the
  fabric sustains.

* ``fig15.recovery.kill1of4`` — kill-a-shard recovery time: with a
  4-shard/replication-2 fabric under a live write workload, SIGKILL one
  shard and time from the kill to the first successful failover read of
  a key whose PRIMARY was the victim.  Also asserts the zero-lost-puts
  guarantee: every put acked before or after the kill must resolve
  (``lost_puts`` is recorded and must be 0).

``run(micro=True)`` is the perf-gate tier: 1- and 4-shard aggregate rows
plus the recovery row, fewer reps.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

from benchmarks.util import emit, fmt_bytes, payload, record, time_call, tmpdir
from repro.core import serialize
from repro.core.deploy import start_kvserver
from repro.core.fabric import ShardedConnector
from repro.distributed.chaos import kill_shard

SIZE = 1_000_000
BATCH = 8
SHARD_COUNTS = [1, 2, 4, 8]
MICRO_SHARD_COUNTS = [1, 4]


def _spawn_fabric(d: str, n: int, tag: str,
                  op_timeout: float = 30.0):
    handles = [start_kvserver(d, name=f"{tag}{i}", uds=True)
               for i in range(n)]
    fab = ShardedConnector([h.host for h in handles],
                           replication=min(2, n), quorum=True,
                           op_timeout=op_timeout)
    return handles, fab


def _agg_row(n: int, micro: bool) -> tuple[float, float]:
    """One shard-count row; returns (served_mb_per_s, goodput_mb_per_s)."""
    d = tmpdir(f"fig15-{n}")
    handles, fab = _spawn_fabric(d, n, "agg")
    try:
        frames = [serialize(payload(SIZE, seed=i)) for i in range(BATCH)]
        nbytes = sum(f.nbytes for f in frames)

        def rt() -> None:
            with fab.pipeline() as p:
                keys = p.put_batch(frames)
                h = p.get_batch(keys)
                p.evict_batch(keys)
            got = h.result()
            assert all(b is not None for b in got)

        samples = 5 if micro else 9
        for _ in range(3):
            rt()                               # warm: conns + allocator
        t = min(time_call(rt, reps=1, warmup=0, inner=1)
                for _ in range(samples))
        served = nbytes * (fab.replication + 1) / t / 1e6
        goodput = nbytes * 2 / t / 1e6
        emit(f"fig15.agg.{n}shard.{fmt_bytes(SIZE)}", t * 1e6,
             f"{served:.0f}MB/s served r{fab.replication} "
             f"({goodput:.0f} goodput)", mb_per_s=served)
        return served, goodput
    finally:
        fab.close()
        for h in handles:
            h.stop()


def _recovery_row(micro: bool) -> dict:
    """SIGKILL one of 4 shards mid-workload; time to first failover read."""
    d = tmpdir("fig15-recovery")
    handles, fab = _spawn_fabric(d, 4, "rec", op_timeout=5.0)
    try:
        # committed pre-kill puts (small: recovery latency, not bandwidth)
        frames = [serialize(payload(10_000, seed=i)) for i in range(64)]
        keys = fab.put_batch(frames)
        # the victim is shard 0; probe key = one whose PRIMARY is the
        # victim, so its first post-kill read MUST fail over
        victim = handles[0]
        probe = next(k for k in keys
                     if fab.ring.primary(k[1]) == victim.host)
        # live writers keep putting through the kill
        acked: list = []
        stop = threading.Event()

        def writer() -> None:
            while not stop.is_set():
                try:
                    acked.append(fab.put(b"mid-kill-write" * 64))
                except ConnectionError:
                    pass           # unacked: allowed to be lost

        wt = threading.Thread(target=writer, daemon=True)
        wt.start()
        time.sleep(0.05)
        t0 = time.perf_counter()
        kill_shard(victim)
        while True:                # first successful failover read
            if fab.get(probe) is not None:
                break
            if time.perf_counter() - t0 > 30.0:
                raise TimeoutError("failover read never succeeded")
        recovery_s = time.perf_counter() - t0
        stop.set()
        wt.join(timeout=5.0)
        # zero committed puts lost: every acked key resolves via failover
        lost = sum(b is None for b in fab.get_batch(keys + acked))
        emit("fig15.recovery.kill1of4", recovery_s * 1e6,
             f"{recovery_s * 1e3:.1f}ms, {lost} lost of "
             f"{len(keys) + len(acked)}")
        return {"recovery_ms": round(recovery_s * 1e3, 1),
                "lost_puts": lost,
                "committed_puts": len(keys) + len(acked),
                "n_failovers": fab.n_failovers}
    finally:
        fab.close()
        for h in handles:
            h.stop()


def _fig6_baseline() -> float | None:
    """The committed single-server baseline this run is compared against
    (``fig6.kvserver.977KB`` in BENCH_fig6.json), if present."""
    path = Path(__file__).resolve().parents[1] / "BENCH_fig6.json"
    try:
        data = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    rows = data.get("rows", data) if isinstance(data, dict) else data
    for row in rows:
        if row.get("name") == f"fig6.kvserver.{fmt_bytes(SIZE)}":
            return row.get("mb_per_s")
    return None


def run(micro: bool = False) -> None:
    results: dict = {}
    for n in (MICRO_SHARD_COUNTS if micro else SHARD_COUNTS):
        served, goodput = _agg_row(n, micro)
        results[f"agg_mb_per_s_{n}shard"] = round(served, 1)
        results[f"goodput_mb_per_s_{n}shard"] = round(goodput, 1)
    results.update(_recovery_row(micro))
    if results.get("agg_mb_per_s_1shard"):
        results["scaling_4shard_vs_1"] = round(
            results.get("agg_mb_per_s_4shard", 0.0)
            / results["agg_mb_per_s_1shard"], 2)
    baseline = _fig6_baseline()
    if baseline:
        # the acceptance bar: 4-shard aggregate vs 2x the single-server
        # fig6 row — both the served and the stricter goodput ratio
        results["fig6_kvserver_baseline_mb_per_s"] = baseline
        results["bar_2x_baseline_mb_per_s"] = round(2 * baseline, 1)
        results["agg_4shard_vs_2x_baseline"] = round(
            results.get("agg_mb_per_s_4shard", 0.0) / (2 * baseline), 2)
        results["goodput_4shard_vs_2x_baseline"] = round(
            results.get("goodput_mb_per_s_4shard", 0.0) / (2 * baseline), 2)
    record("fabric", results)


if __name__ == "__main__":
    run()
