"""Fig 8 analog: PS-endpoint get/set latency vs concurrent clients.

The endpoint is a single-threaded asyncio app (as in the paper), so
per-request time scales ~linearly with client count — reproduced here.
"""
from __future__ import annotations

import threading
import time

from benchmarks.util import emit, fmt_bytes, payload, tmpdir
from repro.core import join_frame, serialize
from repro.core.connectors import EndpointConnector
from repro.core.deploy import start_endpoint, start_relay

SIZES = [100_000, 1_000_000]
CLIENTS = [1, 2, 4]
REQS = 20


def run() -> None:
    d = tmpdir("fig8")
    relay = start_relay(d)
    ep = start_endpoint(d, relay.address, name="fig8")
    for size in SIZES:
        blob = join_frame(serialize(payload(size)))
        for n_clients in CLIENTS:
            times: list[float] = []
            lock = threading.Lock()

            def client():
                conn = EndpointConnector(address=ep.address)
                for _ in range(REQS):
                    t0 = time.perf_counter()
                    key = conn.put(blob)
                    got = conn.get(key)
                    dt = time.perf_counter() - t0
                    assert got == blob
                    conn.evict(key)
                    with lock:
                        times.append(dt)
                conn.close()

            threads = [threading.Thread(target=client)
                       for _ in range(n_clients)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            avg = sum(times) / len(times)
            emit(f"fig8.setget.{fmt_bytes(size)}.c{n_clients}",
                 avg * 1e6, f"{n_clients}-clients")
    ep.stop()
    relay.stop()


if __name__ == "__main__":
    run()
