"""Fig 8 analog: PS-endpoint get/set throughput vs concurrent clients.

The endpoint is a single-threaded asyncio app (as in the paper), so serial
clients see per-request time scale ~linearly with client count.  Since the
pipelined transport the interesting number is *aggregate throughput*: batch
ops stream every request before waiting, so N round trips collapse to ~1
and the wire stays full.

Modes per (size, client-count):

* ``serial``    — the pre-PR access pattern: one blocking put/get round
  trip at a time per client.
* ``pipelined`` — ``put_batch``/``get_batch``/``evict_batch``: all
  requests in flight on one connection per client.

``fig8.store_batch`` compares looped ``Store.get`` against one batched
``Store.get_batch`` (a single ``mget2`` exchange) for 32 x 256 KB objects.

``BASELINE_PRE_PR`` pins the numbers measured at commit e543dfb (serial
one-request-in-flight KVClient, msgpack-embedded endpoint payloads) so
``BENCH_fig8.json`` always records before/after.
"""
from __future__ import annotations

import multiprocessing as mp
import time

from benchmarks.util import (emit, fmt_bytes, payload, record, time_call,
                             tmpdir)
from repro.core import Store, join_frame, serialize
from repro.core.connectors import EndpointConnector, KVServerConnector
from repro.core.deploy import start_endpoint, start_kvserver, start_relay

SIZES = [100_000, 1_000_000]
CLIENTS = [1, 2, 4]
REQS = 20
BATCH_N, BATCH_SIZE = 32, 256 * 1024

# measured at commit e543dfb (pre-pipelining) with THIS harness (process
# clients, best-of-3, span-based aggregate), mean of 2 runs on this host
BASELINE_PRE_PR = {
    "setget.98KB.c1.serial.aggregate_MBps": 189.7,
    "setget.98KB.c4.serial.aggregate_MBps": 153.4,
    "store_get_loop_32x256KB_ms": 30.1,
}


def _client_proc(ep_address: str, blob: bytes, pipelined: bool,
                 q: "mp.Queue", barrier) -> None:
    conn = EndpointConnector(address=ep_address)
    k = conn.put(b"warm")            # connection + code-path warmup
    conn.get(k)
    conn.evict(k)
    barrier.wait()                   # align every client's request window
    t0 = time.perf_counter()
    if pipelined:
        keys = conn.put_batch([blob] * REQS)
        got = conn.get_batch(keys)
        dt = time.perf_counter() - t0
        assert all(g == blob for g in got)
        conn.evict_batch(keys)
    else:
        for _ in range(REQS):
            key = conn.put(blob)
            got = conn.get(key)
            assert got == blob
            conn.evict(key)
        dt = time.perf_counter() - t0
    conn.close()
    q.put(dt)


def _run_once(ep_address: str, blob: bytes, n_clients: int,
              pipelined: bool) -> tuple[float, float]:
    """Independent client *processes* (as in the paper's Fig 8 — threads
    would serialize the clients on the benchmark's own GIL).  Returns
    (avg_op_s, span_s) where span is the slowest client's request window,
    measured inside the client so process startup is excluded."""
    method = ("fork" if "fork" in mp.get_all_start_methods() else None)
    ctx = mp.get_context(method)
    q = ctx.Queue()
    barrier = ctx.Barrier(n_clients)
    procs = [ctx.Process(target=_client_proc,
                         args=(ep_address, blob, pipelined, q, barrier))
             for _ in range(n_clients)]
    for p in procs:
        p.start()
    try:
        dts = [q.get(timeout=120) for _ in procs]
    except Exception:
        for p in procs:
            p.terminate()
        raise RuntimeError(
            "fig8 client died before reporting; exit codes: "
            f"{[p.exitcode for p in procs]}")
    for p in procs:
        p.join()
    span = max(dts)
    avg_op = sum(dts) / len(dts) / REQS
    return avg_op, span


def _run_clients(ep_address: str, blob: bytes, n_clients: int,
                 pipelined: bool, reps: int = 3) -> tuple[float, float]:
    """Best of ``reps`` runs — scheduler noise between the client
    processes and the single endpoint process dominates the tail on small
    hosts."""
    runs = [_run_once(ep_address, blob, n_clients, pipelined)
            for _ in range(reps)]
    return min(runs, key=lambda r: r[1])


def run() -> None:
    d = tmpdir("fig8")
    relay = start_relay(d)
    ep = start_endpoint(d, relay.address, name="fig8")
    results: dict = {"baseline_pre_pr": dict(BASELINE_PRE_PR)}
    _run_once(ep.address, b"x" * 10_000, 1, True)   # warm the endpoint
    for size in SIZES:
        blob = join_frame(serialize(payload(size)))
        for n_clients in CLIENTS:
            for mode, pipelined in (("serial", False), ("pipelined", True)):
                avg_op, span = _run_clients(ep.address, blob, n_clients,
                                            pipelined)
                agg = len(blob) * 2 * REQS * n_clients / span / 1e6
                tag = f"setget.{fmt_bytes(size)}.c{n_clients}.{mode}"
                emit(f"fig8.{tag}", avg_op * 1e6, f"{agg:.0f}MB/s")
                results[f"{tag}.aggregate_MBps"] = round(agg, 1)
    ep.stop()
    relay.stop()
    time.sleep(1.0)        # let the stopped processes drain off the cores

    # -- Store.get_batch vs looped Store.get (single mget2 vs N round trips)
    kv = start_kvserver(d)
    store = Store("fig8-batch", KVServerConnector(kv.host, kv.port),
                  cache_size=0, register=False)
    objs = [payload(BATCH_SIZE, seed=i) for i in range(BATCH_N)]
    keys = store.put_batch(objs)

    def best(fn, reps: int = 7) -> float:
        # min-of-N: scheduler noise on small hosts only ever adds time
        fn()
        return min(time_call(fn, reps=1, warmup=0) for _ in range(reps))

    t_loop = best(lambda: [store.get(k) for k in keys])
    t_batch = best(lambda: store.get_batch(keys))
    label = f"{BATCH_N}x{fmt_bytes(BATCH_SIZE)}"
    emit(f"fig8.store_get_loop.{label}", t_loop * 1e6)
    emit(f"fig8.store_get_batch.{label}", t_batch * 1e6,
         f"{t_loop / t_batch:.1f}x")
    results.update({
        f"store_get_loop_{label}_ms": round(t_loop * 1e3, 2),
        f"store_get_batch_{label}_ms": round(t_batch * 1e3, 2),
        f"store_get_batch_speedup": round(t_loop / t_batch, 2),
    })
    store.close(close_connector=True)
    kv.stop()
    record("fig8", results)


if __name__ == "__main__":
    run()
