"""Fig 14 (this repo): proxy-native serving vs the classic pickle plane.

Two comparisons over the same tiny decoder (identical compute; only the
data plane and the scheduler differ):

* ``fig14.pickle_socket.bN`` vs ``fig14.proxy_stream.bN`` — request/response
  throughput at batch N.  Each request carries a context-features blob
  (the data-plane payload serving systems actually ship — retrieval
  context, patch embeddings, speculative prefixes).  The baseline hauls
  every request through ``pickle.dumps`` → socket → ``pickle.loads``
  (full copies at each hop) into a static lockstep batcher; the
  proxy-native path appends ``evict=True`` proxies to a ``ProxyStream``
  that feeds :meth:`ServeEngine.serve_stream` — payload bytes land in the
  shm arena once and the engine resolves them in place.

* ``fig14.static.p99`` vs ``fig14.continuous.p99`` — tail latency under
  MIXED ``max_new_tokens``.  The lockstep batcher holds every row hostage
  to its batch's longest request (and queues whole batches sequentially);
  continuous batching retires rows at their own length and admits queued
  requests into the freed slots.

Also recorded: ``fig14.weights.*`` — one-worker weight delivery, pickle
round-trip copy vs borrowed-proxy resolve into zero-copy arena views.

The run writes ``BENCH_serve.json`` (registered as tag ``serve`` in
``benchmarks.run``); ``perf_gate`` gates ``fig14.proxy_stream.b8``'s
``req_per_s`` against the committed baseline.
"""
from __future__ import annotations

import pickle
import socket
import struct
import threading
import time

import numpy as np

from benchmarks.util import emit, fmt_bytes, record, tmpdir
from repro.configs import ARCHS
from repro.core import Store
from repro.core.connectors import SharedMemoryConnector
from repro.core.proxy import extract, get_factory, is_proxy
from repro.serve.engine import Request, ServeEngine, _ListSource

PLEN = 32
NEW_TOKENS = 8
CTX_BYTES = 16 << 20         # per-request context-features payload
MAX_BATCH = 8
MIX_REQS = 16                # part B: mixed-length tail-latency run
MIX_SHORT, MIX_LONG = 4, 24


def _cfg():
    return ARCHS["qwen2.5-14b"].reduced().replace(dtype="float32",
                                                  n_layers=2)


def _payloads(n: int, ctx_bytes: int, mnt: int = NEW_TOKENS) -> list[dict]:
    rng = np.random.default_rng(42)
    ctx = rng.standard_normal(max(ctx_bytes // 4, 1)).astype(np.float32)
    # distinct array per request — a shared object would let pickle's memo
    # serialize the payload once and undercount the baseline's copies
    return [{"prompt": list(map(int, rng.integers(1, 512, size=PLEN))),
             "max_new_tokens": mnt, "temperature": 0.0,
             "req_id": f"req-{i}", "context": ctx + np.float32(i)}
            for i in range(n)]


# ---------------------------------------------------------------------------
# baseline: pickle over a socket into a static lockstep batcher
# ---------------------------------------------------------------------------
def _send_frame(sock, obj) -> None:
    buf = pickle.dumps(obj)
    sock.sendall(struct.pack("<Q", len(buf)) + buf)


def _recv_frame(sock):
    n = struct.unpack("<Q", _recv_exact(sock, 8))[0]
    return pickle.loads(_recv_exact(sock, n))


def _recv_exact(sock, n: int) -> bytes:
    chunks = []
    while n:
        c = sock.recv(min(n, 1 << 20))
        if not c:
            raise ConnectionError("peer closed")
        chunks.append(c)
        n -= len(c)
    return b"".join(chunks)


def _pickle_server(sock, engine: ServeEngine) -> None:
    while True:
        batch = _recv_frame(sock)
        if batch is None:
            return
        reqs = [Request(prompt=d["prompt"],
                        max_new_tokens=d["max_new_tokens"],
                        temperature=d["temperature"]) for d in batch]
        outs = []
        for s in range(0, len(reqs), engine.max_batch):
            outs.extend(engine.generate(
                reqs[s:s + engine.max_batch])["outputs"])
        _send_frame(sock, outs)


def run_pickle(engine: ServeEngine, payloads: list[dict]) -> float:
    client, server = socket.socketpair()
    t = threading.Thread(target=_pickle_server, args=(server, engine))
    t.start()
    t0 = time.perf_counter()
    _send_frame(client, payloads)
    outs = _recv_frame(client)
    dt = time.perf_counter() - t0
    _send_frame(client, None)
    t.join()
    client.close(), server.close()
    assert len(outs) == len(payloads)
    return dt


# ---------------------------------------------------------------------------
# proxy-native: evict-proxies on a ProxyStream into the continuous engine
# ---------------------------------------------------------------------------
def run_proxy(engine: ServeEngine, store: Store,
              payloads: list[dict], topic: str) -> float:
    t0 = time.perf_counter()

    def feed() -> None:
        # requests ride as plain leased proxies: the engine resolves them
        # to in-place arena views (no receive copy); the lease reclaims
        # the slot afterwards.  Responses go back evict=True (ephemeral).
        prod = store.stream_producer(f"{topic}-req")
        for d in payloads:
            prod.append(store.proxy(d, ttl=120.0))
        prod.close()

    t = threading.Thread(target=feed)
    t.start()
    engine.serve_stream(store, f"{topic}-req", f"{topic}-res",
                        data_store=store, timeout=30.0)
    t.join()
    n = 0
    for item in store.stream_consumer(f"{topic}-res", timeout=10.0):
        if is_proxy(item):
            item = extract(item)
        assert item["tokens"], f"empty completion: {item}"
        n += 1
    dt = time.perf_counter() - t0
    assert n == len(payloads)
    return dt


# ---------------------------------------------------------------------------
# part B: tail latency, lockstep vs continuous, mixed max_new_tokens
# ---------------------------------------------------------------------------
def _mixed_reqs() -> list[Request]:
    rng = np.random.default_rng(7)
    return [Request(prompt=list(map(int, rng.integers(1, 512, size=PLEN))),
                    max_new_tokens=MIX_LONG if i % 2 else MIX_SHORT,
                    req_id=f"mix-{i}")
            for i in range(MIX_REQS)]


def run_static_tail(engine: ServeEngine) -> list[float]:
    reqs = _mixed_reqs()
    lats: list[float] = []
    t0 = time.perf_counter()
    for s in range(0, len(reqs), engine.max_batch):
        chunk = reqs[s:s + engine.max_batch]
        engine.generate(chunk)
        done = time.perf_counter() - t0       # whole batch lands together
        lats.extend([done] * len(chunk))
    return lats


def run_continuous_tail(engine: ServeEngine) -> list[float]:
    reqs = _mixed_reqs()
    lats: list[float] = []
    t0 = time.perf_counter()
    engine._run_continuous(_ListSource(reqs),
                           lambda c: lats.append(time.perf_counter() - t0))
    return lats


def _p99(lats: list[float]) -> float:
    return float(np.percentile(np.asarray(lats), 99))


# ---------------------------------------------------------------------------
def run(micro: bool = False) -> None:
    cfg = _cfg()
    engine = ServeEngine(cfg, max_batch=MAX_BATCH,
                         max_context=PLEN + MIX_LONG + 8, block_tokens=32)
    static = ServeEngine(cfg, params=engine.params, max_batch=MAX_BATCH)
    static._continuous = False

    reg = tmpdir("fig14")
    store = Store("fig14-serve", SharedMemoryConnector(reg))

    # jit warmup for every timed shape (prefill/decode/insert traces)
    warm = [Request(prompt=[1] * PLEN, max_new_tokens=2)
            for _ in range(MAX_BATCH)]
    engine.generate(warm)
    for b in ((MAX_BATCH,) if micro else (2, MAX_BATCH)):
        static.generate([Request(prompt=[1] * PLEN,
                                 max_new_tokens=NEW_TOKENS)] * b)

    # -- part A: request/response throughput -------------------------------
    ctx_bytes = CTX_BYTES // 4 if micro else CTX_BYTES
    batches = (8,) if micro else (2, 8, 16)
    # untimed priming round: grows the arena slabs / socket buffers once so
    # the timed rounds measure steady-state serving, not cold mmap faults
    run_id = f"{time.monotonic_ns():x}"    # stream topics are single-use
    prime = _payloads(max(batches), ctx_bytes)
    run_pickle(static, prime)
    run_proxy(engine, store, prime, f"{run_id}-prime")
    for n in batches:
        payloads = _payloads(n, ctx_bytes)
        dt_p = min(run_pickle(static, payloads) for _ in range(2))
        dt_x = min(run_proxy(engine, store, payloads, f"{run_id}-b{n}-{i}")
                   for i in range(2))
        emit(f"fig14.pickle_socket.b{n}", dt_p / n * 1e6,
             f"{n} reqs x {fmt_bytes(ctx_bytes)} ctx, static lockstep",
             req_per_s=n / dt_p)
        emit(f"fig14.proxy_stream.b{n}", dt_x / n * 1e6,
             f"{n} reqs x {fmt_bytes(ctx_bytes)} ctx, continuous",
             req_per_s=n / dt_x)
        record("serve", {f"req_per_s.b{n}": {
            "pickle_socket": round(n / dt_p, 2),
            "proxy_stream": round(n / dt_x, 2),
            "speedup": round(dt_p / dt_x, 2)}})

    if micro:
        store.close()
        engine.close()
        return

    # -- part B: p99 latency under mixed max_new_tokens ---------------------
    # warm the static decode width for the mixed batch, then time both
    static.generate([Request(prompt=[1] * PLEN, max_new_tokens=MIX_LONG),
                     Request(prompt=[1] * PLEN, max_new_tokens=MIX_SHORT)]
                    * (MAX_BATCH // 2))
    run_continuous_tail(engine)                   # untimed warm round
    lat_s = min((run_static_tail(static) for _ in range(2)), key=_p99)
    lat_c = min((run_continuous_tail(engine) for _ in range(2)), key=_p99)
    emit("fig14.static.p99", _p99(lat_s) * 1e6,
         f"{MIX_REQS} reqs, max_new_tokens {MIX_SHORT}/{MIX_LONG} mixed")
    emit("fig14.continuous.p99", _p99(lat_c) * 1e6,
         f"{MIX_REQS} reqs, max_new_tokens {MIX_SHORT}/{MIX_LONG} mixed")
    record("serve", {"p99_s": {
        "static": round(_p99(lat_s), 4),
        "continuous": round(_p99(lat_c), 4),
        "speedup": round(_p99(lat_s) / _p99(lat_c), 2)},
        "mean_s": {"static": round(float(np.mean(lat_s)), 4),
                   "continuous": round(float(np.mean(lat_c)), 4)}})

    # -- weight delivery: pickle round trip vs borrowed-proxy resolve -------
    host = {k: np.asarray(v) for k, v in
            enumerate_leaves(engine.params)}
    nbytes = sum(a.nbytes for a in host.values())
    t0 = time.perf_counter()
    blob = pickle.dumps(host)
    _ = pickle.loads(blob)
    dt_p = time.perf_counter() - t0
    owned = engine.publish_weights(store, ttl=120.0)
    key = get_factory(owned).key
    store.cache.pop(key)          # a fresh worker has no warm cache
    t0 = time.perf_counter()
    view_tree = store.get(key)    # zero-copy arena views
    dt_x = time.perf_counter() - t0
    assert view_tree is not None
    emit("fig14.weights.pickle", dt_p * 1e6,
         f"{fmt_bytes(nbytes)} params, dumps+loads (full copies)",
         mb_per_s=nbytes / dt_p / 1e6)
    emit("fig14.weights.proxy", dt_x * 1e6,
         f"{fmt_bytes(nbytes)} params, shm views",
         mb_per_s=nbytes / dt_x / 1e6)
    record("serve", {"weights": {
        "nbytes": nbytes,
        "pickle_s": round(dt_p, 5), "proxy_s": round(dt_x, 5)}})

    store.close()
    engine.close()


def enumerate_leaves(tree):
    import jax

    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves]


if __name__ == "__main__":
    run()
