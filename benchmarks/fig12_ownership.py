"""Fig 12 (this repo): object lifecycle — refcounted fan-out vs legacy evict.

The follow-on ownership work (arXiv:2407.01764) motivates this figure: an
ephemeral intermediate consumed by N workers.  With the paper's original
fire-and-forget ``evict=True`` flag the FIRST consumer to resolve evicts the
key and every other consumer raises ``LookupError``; with refcounted keys
every consumer resolves and the key is evicted exactly once, after the last
reference drops — no leaked keys, no errors.

Rows:

* ``fig12.legacy.N*``   — hand-built pre-ownership factories (no refcount,
  resolved without transit so no reference is ever acquired): demonstrates
  the defect — every consumer after the first fails.
* ``fig12.refcount.N*`` — N sibling ``evict=True`` proxies, each pickled
  (as communicated proxies are) and BOTH the local and the wire copy
  resolved concurrently from a thread pool: 2N consumers, zero failures,
  key evicted exactly once.
* ``fig12.owned.N*``    — one ``OwnedProxy`` + ``clone`` per consumer,
  released after use (the explicit-ownership variant of the same fan-out).
* ``fig12.lease``       — keys under a TTL lease whose holders are gone:
  time until the server's lazy expiry sweep reclaims all of them.
"""
from __future__ import annotations

import pickle
import time
from concurrent.futures import ThreadPoolExecutor

from benchmarks.util import emit, payload, record, time_call, tmpdir
from repro.core import Store, clone, release, unregister_store
from repro.core.connectors import KVServerConnector
from repro.core.deploy import start_kvserver
from repro.core.proxy import Proxy
from repro.core.store import StoreFactory

SIZE = 1_000_000
FANOUTS = [4, 16]


def _consume(p) -> int:
    """Resolve one proxy; 1 on the legacy defect's LookupError."""
    try:
        assert p.nbytes > 0
        return 0
    except Exception:  # noqa: BLE001 - ProxyResolveError(LookupError)
        return 1


def run() -> None:
    d = tmpdir("fig12")
    kv = start_kvserver(d)
    store = Store("fig12", KVServerConnector(kv.host, kv.port))
    data = payload(SIZE)
    results: dict = {}

    with ThreadPoolExecutor(max_workers=8) as pool:
        for n in FANOUTS:
            # -- legacy fire-and-forget evict: the defect ------------------
            key = store.put(data)
            legacy = [Proxy(StoreFactory(key=tuple(key),
                                         store_config=store.config(),
                                         evict=True)) for _ in range(n)]
            failures = sum(_consume(p) for p in legacy)   # deterministic
            emit(f"fig12.legacy.N{n}", 0.0, f"{failures}/{n} LookupErrors")
            results[f"legacy_failures_N{n}"] = failures

            # -- refcounted siblings: everyone resolves, key dies once -----
            def refcounted(n=n):
                key = store.put(data)
                sibs = [store.proxy_from_key(key, evict=True)
                        for _ in range(n)]
                wire = [pickle.loads(pickle.dumps(p)) for p in sibs]
                assert sum(pool.map(_consume, sibs + wire)) == 0
                assert not store.exists(key)   # ...and cleaned up exactly

            t = time_call(refcounted)
            srv = store.stats()["connector"]
            emit(f"fig12.refcount.N{n}", t * 1e6,
                 f"{srv['n_objects']} leaked")
            results[f"refcount_N{n}_ms"] = round(t * 1e3, 2)
            results[f"refcount_N{n}_leaked"] = srv["n_objects"]

            # -- explicit ownership: clone per consumer, release after use -
            def owned(n=n):
                owner = store.owned_proxy(data, ttl=60)

                def consume_owned(c):
                    w = pickle.loads(pickle.dumps(c))  # transit clones a ref
                    assert w.nbytes > 0
                    release(w)
                    release(c)

                list(pool.map(consume_owned,
                              [clone(owner) for _ in range(n - 1)]))
                release(owner)

            t = time_call(owned)
            emit(f"fig12.owned.N{n}", t * 1e6)
            results[f"owned_N{n}_ms"] = round(t * 1e3, 2)

    # -- lease reclamation: holders are gone, the server sweep cleans up ---
    n_keys = 32
    keys = store.put_batch([payload(10_000, seed=i) for i in range(n_keys)])
    store.connector.incref_batch([tuple(k) for k in keys])
    store.connector.touch_batch([tuple(k) for k in keys], 0.3)
    t0 = time.perf_counter()
    while store.stats()["connector"]["n_objects"] and \
            time.perf_counter() - t0 < 10:
        time.sleep(0.05)
    reclaim_s = time.perf_counter() - t0
    srv = store.stats()["connector"]
    emit("fig12.lease", reclaim_s * 1e6,
         f"{srv['n_expired']} expired, {srv['n_objects']} left")
    results["lease_reclaim_s"] = round(reclaim_s, 2)
    results["lease_expired"] = srv["n_expired"]
    results["final_n_objects"] = srv["n_objects"]
    record("fig12", results)

    store.close()
    unregister_store("fig12")
    kv.stop()


if __name__ == "__main__":
    run()
