"""Fig 6 analog: distributed in-memory connector comparison.

Paper: Margo/UCX (RDMA) vs ZMQ vs Redis vs DataSpaces.  Here: shm (the
zero-copy intra-node analog) vs socket store (ZMQ role) vs standalone KV
server (Redis role) vs file system — a full object round trip per connector
(serialize -> put -> get -> deserialize), which is what the Store hot path
pays.  PSJ2 frames gather-write the array payload segments and deserialize
as zero-copy views over the received frame.

``fig6.serdes*`` rows isolate the serializer: the legacy PSJ1 path
(inline-copy msgpack body) vs the PSJ2 multi-buffer frame.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.util import emit, fmt_bytes, payload, time_call, tmpdir
from repro.core import deserialize, serialize, serialize_v1
from repro.core.connectors import (FileConnector, KVServerConnector,
                                   SharedMemoryConnector, SocketConnector)
from repro.core.deploy import start_kvserver

SIZES = [10_000, 1_000_000, 10_000_000, 100_000_000]


def run() -> None:
    d = tmpdir("fig6")
    kv = start_kvserver(d)
    conns = {
        "shm": SharedMemoryConnector(os.path.join(d, "shm")),
        "socket": SocketConnector(os.path.join(d, "sock")),
        "kvserver": KVServerConnector(kv.host, kv.port),
        "file": FileConnector(os.path.join(d, "file")),
    }
    for size in SIZES:
        data = payload(size)
        nbytes = serialize(data).nbytes

        t = time_call(lambda: deserialize(serialize_v1(data)))
        emit(f"fig6.serdes-v1.{fmt_bytes(size)}", t * 1e6, "PSJ1")
        t = time_call(lambda: deserialize(serialize(data)))
        emit(f"fig6.serdes.{fmt_bytes(size)}", t * 1e6, "PSJ2")

        for name, conn in conns.items():
            def rt(conn=conn):
                key = conn.put(serialize(data))
                got = deserialize(conn.get(key))
                assert np.asarray(got).nbytes == data.nbytes
                conn.evict(key)

            t = time_call(rt)
            mbps = nbytes * 2 / t / 1e6
            emit(f"fig6.{name}.{fmt_bytes(size)}", t * 1e6,
                 f"{mbps:.0f}MB/s")
    for conn in conns.values():
        conn.close()
    kv.stop()


if __name__ == "__main__":
    run()
