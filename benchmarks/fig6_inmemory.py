"""Fig 6 analog: distributed in-memory connector comparison.

Paper: Margo/UCX (RDMA) vs ZMQ vs Redis vs DataSpaces.  Here: shm (the
zero-copy intra-node analog) vs socket store (ZMQ role) vs standalone KV
server (Redis role) vs file system — put+get round trip per connector.
"""
from __future__ import annotations

import os

from benchmarks.util import emit, fmt_bytes, payload, time_call, tmpdir
from repro.core import serialize
from repro.core.connectors import (FileConnector, KVServerConnector,
                                   SharedMemoryConnector, SocketConnector)
from repro.core.deploy import start_kvserver

SIZES = [10_000, 1_000_000, 10_000_000, 100_000_000]


def run() -> None:
    d = tmpdir("fig6")
    kv = start_kvserver(d)
    conns = {
        "shm": SharedMemoryConnector(os.path.join(d, "shm")),
        "socket": SocketConnector(os.path.join(d, "sock")),
        "kvserver": KVServerConnector(kv.host, kv.port),
        "file": FileConnector(os.path.join(d, "file")),
    }
    for size in SIZES:
        blob = serialize(payload(size))

        for name, conn in conns.items():
            def rt(conn=conn):
                key = conn.put(blob)
                got = conn.get(key)
                assert got is not None and len(got) == len(blob)
                conn.evict(key)

            t = time_call(rt)
            mbps = len(blob) * 2 / t / 1e6
            emit(f"fig6.{name}.{fmt_bytes(size)}", t * 1e6,
                 f"{mbps:.0f}MB/s")
    for conn in conns.values():
        conn.close()
    kv.stop()


if __name__ == "__main__":
    run()
