"""Fig 6 analog: distributed in-memory connector comparison.

Paper: Margo/UCX (RDMA) vs ZMQ vs Redis vs DataSpaces.  Here: shm (the
slab-arena zero-copy intra-node analog) vs socket store (ZMQ role) vs
standalone KV server (Redis role) vs file system — a full object round
trip per connector (serialize -> put -> get -> deserialize -> evict),
which is what the Store hot path pays.  PSJ2 frames gather-write the
array payload segments; the shm path memcpys them into an arena slot and
deserializes zero-copy out of the mapping, the KV path recv_intos them
into their final buffers on both sides.

``fig6.serdes*`` rows isolate the serializer: the legacy PSJ1 path
(inline-copy msgpack body) vs the PSJ2 multi-buffer frame.

``run(micro=True)`` is the CI perf-gate tier: the two smallest sizes,
fewer reps, no batch section — a few seconds, enough to catch a data-
plane regression (see ``benchmarks.perf_gate``).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.util import emit, fmt_bytes, payload, record, time_call, tmpdir
from repro.core import deserialize, serialize, serialize_v1
from repro.core.connectors import (FileConnector, KVServerConnector,
                                   SharedMemoryConnector, SocketConnector)
from repro.core.deploy import start_kvserver

SIZES = [10_000, 1_000_000, 10_000_000, 100_000_000]
MICRO_SIZES = [10_000, 1_000_000]
BATCH_N, BATCH_SIZE = 32, 64 * 1024


def run(micro: bool = False) -> None:
    d = tmpdir("fig6")
    kv = start_kvserver(d)
    conns = {
        "shm": SharedMemoryConnector(os.path.join(d, "shm")),
        "socket": SocketConnector(os.path.join(d, "sock")),
        "kvserver": KVServerConnector(kv.host, kv.port),
        "file": FileConnector(os.path.join(d, "file")),
    }
    for size in (MICRO_SIZES if micro else SIZES):
        # single-shot round trips in this container carry multi-ms
        # scheduler spikes: amortize calls per sample (and median a few
        # samples) so the recorded rows estimate steady-state per-call
        # cost.  Bigger tiers amortize less to bound wall time; 95 MB
        # stays single-shot.
        if size <= 1_000_000:
            reps, inner = 5, 8
        elif size <= 10_000_000:
            reps, inner = 5, 4
        else:
            reps, inner = 3, 1
        data = payload(size)
        nbytes = serialize(data).nbytes

        if micro:
            reps, inner = 3, 8
        else:
            t = time_call(lambda: deserialize(serialize_v1(data)),
                          reps=reps, inner=inner)
            emit(f"fig6.serdes-v1.{fmt_bytes(size)}", t * 1e6, "PSJ1")
        t = time_call(lambda: deserialize(serialize(data)),
                      reps=reps, inner=inner)
        emit(f"fig6.serdes.{fmt_bytes(size)}", t * 1e6, "PSJ2")

        for name, conn in conns.items():
            def rt(conn=conn):
                key = conn.put(serialize(data))
                got = deserialize(conn.get(key))
                assert np.asarray(got).nbytes == data.nbytes
                conn.evict(key)

            t = time_call(rt, reps=reps, inner=inner)
            mbps = nbytes * 2 / t / 1e6
            emit(f"fig6.{name}.{fmt_bytes(size)}", t * 1e6,
                 f"{mbps:.0f}MB/s", mb_per_s=mbps)

    if micro:
        for conn in conns.values():
            conn.close()
        kv.stop()
        return

    # batched vs looped round trips on the KV-backed connectors: put_batch/
    # get_batch collapse N round trips into one pipelined mput2/mget2
    results: dict = {}
    frames = [serialize(payload(BATCH_SIZE, seed=i)) for i in range(BATCH_N)]
    label = f"{BATCH_N}x{fmt_bytes(BATCH_SIZE)}"
    for name in ("socket", "kvserver"):
        conn = conns[name]

        def loop_rt(conn=conn):
            keys = [conn.put(f) for f in frames]
            for k in keys:
                deserialize(conn.get(k))
            for k in keys:
                conn.evict(k)

        def batch_rt(conn=conn):
            keys = conn.put_batch(frames)
            for blob in conn.get_batch(keys):
                deserialize(blob)
            conn.evict_batch(keys)

        t_loop = time_call(loop_rt)
        t_batch = time_call(batch_rt)
        emit(f"fig6.{name}.loop.{label}", t_loop * 1e6)
        emit(f"fig6.{name}.batch.{label}", t_batch * 1e6,
             f"{t_loop / t_batch:.1f}x")
        results[f"{name}_loop_{label}_ms"] = round(t_loop * 1e3, 2)
        results[f"{name}_batch_{label}_ms"] = round(t_batch * 1e3, 2)
        results[f"{name}_batch_speedup"] = round(t_loop / t_batch, 2)
    record("fig6", results)

    for conn in conns.values():
        conn.close()
    kv.stop()


if __name__ == "__main__":
    run()
