"""Fig 5 analog: FaaS round-trip time vs payload size and data path.

Baseline ships task inputs through the payload-capped cloud control plane;
proxy variants ship a ~300-byte reference via File/Socket stores.  The
``sleep`` rows reproduce the bottom half of Fig 5: a 0.2 s task that
``resolve_async``es its input overlaps communication with compute.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.util import emit, fmt_bytes, payload, time_call, tmpdir
from repro.core import Store, resolve_async
from repro.core.connectors import FileConnector, SocketConnector
from repro.core.proxy import extract, is_proxy
from repro.federated.faas import CloudModel, FaasExecutor, PayloadTooLarge

SIZES = [10_000, 1_000_000, 10_000_000]


def noop_task(x):
    if is_proxy(x):
        x = extract(x)   # ensure the data is actually materialized
    return np.asarray(x).shape[0]


def sleep_task(x):
    if is_proxy(x):
        resolve_async(x)
    time.sleep(0.2)
    return np.asarray(extract(x) if is_proxy(x) else x).shape[0]


def run() -> None:
    d = tmpdir("fig5")
    ex = FaasExecutor(n_workers=1, cloud=CloudModel(latency_s=0.01))
    stores = {
        "file": Store("fig5-file", FileConnector(os.path.join(d, "file"))),
        "socket": Store("fig5-sock", SocketConnector(os.path.join(d, "sock"))),
    }
    for size in SIZES:
        data = payload(size)
        # baseline: data by value through the cloud (cap applies)
        try:
            t = time_call(lambda: ex.submit(noop_task, data).result())
            emit(f"fig5.noop.baseline.{fmt_bytes(size)}", t * 1e6,
                 "cloud-value")
        except PayloadTooLarge:
            emit(f"fig5.noop.baseline.{fmt_bytes(size)}", float("nan"),
                 "exceeds-5MB-cap")
        for name, store in stores.items():
            t = time_call(
                lambda: ex.submit(noop_task, store.proxy(data)).result())
            emit(f"fig5.noop.{name}-proxy.{fmt_bytes(size)}", t * 1e6,
                 "proxy")
    # sleep/overlap rows (1 MB)
    data = payload(1_000_000)
    try:
        t = time_call(lambda: ex.submit(sleep_task, data).result(), reps=2)
        emit("fig5.sleep.baseline.1MB", t * 1e6, "cloud-value+0.2s")
    except PayloadTooLarge:
        emit("fig5.sleep.baseline.1MB", float("nan"), "cap")
    t = time_call(
        lambda: ex.submit(sleep_task,
                          stores["file"].proxy(data)).result(), reps=2)
    emit("fig5.sleep.file-proxy.1MB", t * 1e6, "overlap=resolve_async")
    ex.shutdown()
    stores["socket"].connector.shutdown_server()


if __name__ == "__main__":
    run()
