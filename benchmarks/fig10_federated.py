"""Fig 10 analog: FL model-transfer time vs model size.

Per-round dispatch+collect time as the model grows; the by-value baseline
dies at the 5 MB cap (the paper's truncated baseline curve) while proxies
keep a flat control-plane cost.  The compression row shows the int8 update
path (4x fewer bytes through the store).
"""
from __future__ import annotations

import os
import time

from benchmarks.util import emit, fmt_bytes, tmpdir
from repro.configs import ARCHS
from repro.core import Store, frame_nbytes, serialize
from repro.core.connectors import FileConnector
from repro.federated.faas import CloudModel, FaasExecutor, PayloadTooLarge
from repro.federated.fl import FLConfig, FLOrchestrator

WIDTHS = [64, 192, 448]   # ~0.2 / 1.3 / 6.3 MB of weights


def run() -> None:
    d = tmpdir("fig10")
    ex = FaasExecutor(n_workers=2, cloud=CloudModel(latency_s=0.01))
    for width in WIDTHS:
        cfg = ARCHS["phi4-mini-3.8b"].reduced().replace(
            n_layers=2, d_model=width, d_ff=2 * width, vocab=256,
            n_heads=4, n_kv_heads=2, head_dim=width // 4, dtype="float32")
        for transport, compression in (("value", "none"), ("proxy", "none"),
                                       ("proxy", "int8")):
            store = Store(f"fig10-{width}-{transport}-{compression}",
                          FileConnector(os.path.join(d, "store"))) \
                if transport == "proxy" else None
            fl = FLConfig(rounds=1, workers_per_round=2, local_steps=1,
                          transport=transport, compression=compression,
                          batch=2, seq=16)
            orch = FLOrchestrator(cfg, fl, ex, store)
            n_bytes = frame_nbytes(serialize(orch.params))
            try:
                t0 = time.perf_counter()
                info = orch.run_round(0)
                dt = time.perf_counter() - t0
                if info["ok"] == 0:
                    raise PayloadTooLarge("all workers hit the cap")
                emit(f"fig10.{transport}-{compression}.{fmt_bytes(n_bytes)}",
                     dt * 1e6, f"{info['ok']}/2-workers")
            except PayloadTooLarge:
                emit(f"fig10.{transport}-{compression}.{fmt_bytes(n_bytes)}",
                     float("nan"), "exceeds-5MB-cap")
    ex.shutdown()


if __name__ == "__main__":
    run()
