"""Fig 7 analog: % improvement in workflow task round-trip from proxying
task data above a threshold (Colmena-style library integration).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.util import emit, fmt_bytes, payload, tmpdir
from repro.core import Store
from repro.core.connectors import SharedMemoryConnector
from repro.federated.steer import SteerConfig, Steering

SIZES = [10_000, 1_000_000, 10_000_000]
N_TASKS = 8


def run() -> None:
    d = tmpdir("fig7")
    for size in SIZES:
        data = payload(size)

        def task(x):
            return np.float64(np.sum(x))  # tiny result; input dominates

        store = Store(f"fig7-{size}",
                      SharedMemoryConnector(os.path.join(d, f"s{size}")))
        with_p = Steering(SteerConfig(proxy_threshold=100_000), store)
        r1 = with_p.run(task, lambda i: data, N_TASKS)
        with_p.close()
        no_p = Steering(SteerConfig(proxy_threshold=None), None)
        r2 = no_p.run(task, lambda i: data, N_TASKS)
        no_p.close()
        imp = (r2["wall_s"] - r1["wall_s"]) / r2["wall_s"] * 100
        emit(f"fig7.rtt.{fmt_bytes(size)}",
             r1["wall_s"] / N_TASKS * 1e6,
             f"improvement={imp:.0f}%")


if __name__ == "__main__":
    run()
