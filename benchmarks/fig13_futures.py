"""Fig 13 (this repo): futures & streaming — producer/consumer overlap.

The paper's proxy model lets producers "communicate data unilaterally";
the follow-on patterns (arXiv:2407.01764 §futures/§streaming, and the
stream-of-proxies pipelines of arXiv:2410.12092) take that further:
communicate data *before it exists*.  This figure measures exactly that
against the classic produce→put→proxy→consume sequence:

* ``fig13.baseline.*`` — put-then-proxy: the producer computes every chunk,
  puts the batch, mints proxies; only then does the consumer start.  Wall
  clock is production + transfer + consumption, strictly serialized.
* ``fig13.future`` — one pre-data proxy (``Store.future``): the consumer is
  dispatched FIRST and parks in the channel's ``wait``; the producer's
  ``set_result`` releases it.  Measures the consumer's time-to-data beyond
  the producer's own compute (dispatch + transfer ride inside production).
* ``fig13.stream.*`` — ``stream_producer``/``stream_consumer``: chunks flow
  as they are produced, the consumer processes item ``i`` while the
  producer computes ``i+1``.  Wall clock approaches
  ``K * max(T_produce, T_consume)`` instead of the baseline's
  ``K * (T_produce + T_consume)``.

The produce/consume "compute" is a deterministic sleep so the overlap is
the measured quantity, not JIT noise.
"""
from __future__ import annotations

import threading
import time

from benchmarks.util import emit, payload, record, tmpdir
from repro.core import Store, unregister_store
from repro.core.connectors import KVServerConnector
from repro.core.deploy import start_kvserver

N_CHUNKS = 12
CHUNK_BYTES = 250_000
T_PRODUCE = 0.03          # simulated per-chunk producer compute (s)
T_CONSUME = 0.03          # simulated per-chunk consumer compute (s)


def _chunks():
    return [payload(CHUNK_BYTES, seed=i) for i in range(N_CHUNKS)]


def run_baseline(store: Store) -> float:
    """produce all -> put batch -> proxy -> consume all (serialized)."""
    t0 = time.perf_counter()
    produced = []
    for c in _chunks():
        time.sleep(T_PRODUCE)
        produced.append(c)
    proxies = store.proxy_batch(produced, evict=True)
    for p in proxies:
        assert p.nbytes > 0          # resolve
        time.sleep(T_CONSUME)
    return time.perf_counter() - t0


def run_stream(store: Store) -> float:
    """producer streams as it computes; consumer overlaps processing."""
    topic = f"fig13-{time.monotonic_ns()}"
    t0 = time.perf_counter()

    def produce() -> None:
        with store.stream_producer(topic, ttl=60) as prod:
            for c in _chunks():
                time.sleep(T_PRODUCE)
                prod.append(c)

    t = threading.Thread(target=produce)
    t.start()
    n = 0
    for obj in store.stream_consumer(topic, timeout=30):
        assert obj.nbytes > 0
        time.sleep(T_CONSUME)
        n += 1
    t.join()
    assert n == N_CHUNKS
    return time.perf_counter() - t0


def run_future(store: Store) -> tuple[float, float]:
    """consumer dispatched BEFORE the data exists; measures its time-to-
    data beyond the producer's compute (should be ~transfer only)."""
    fut = store.future(timeout=30)
    proxy = fut.proxy()
    done = {}

    def consume() -> None:
        t0 = time.perf_counter()
        assert proxy.nbytes > 0      # parks in wait until set_result
        done["latency"] = time.perf_counter() - t0

    t = threading.Thread(target=consume)
    t.start()
    t_prod = N_CHUNKS * T_PRODUCE / 4
    time.sleep(t_prod)               # the producer's remaining compute
    fut.set_result(payload(CHUNK_BYTES))
    t.join()
    return done["latency"], t_prod


def run() -> None:
    d = tmpdir("fig13")
    kv = start_kvserver(d)
    store = Store("fig13", KVServerConnector(kv.host, kv.port))
    try:
        base_s = run_baseline(store)
        stream_s = run_stream(store)
        fut_latency, fut_prod = run_future(store)

        emit("fig13.baseline.put_then_proxy", base_s * 1e6,
             f"{N_CHUNKS}x{CHUNK_BYTES}B serialized")
        emit("fig13.stream.overlap", stream_s * 1e6,
             f"{base_s / stream_s:.2f}x vs baseline")
        emit("fig13.future.time_to_data", fut_latency * 1e6,
             f"{max(fut_latency - fut_prod, 0) * 1e3:.1f}ms beyond producer")

        floor = N_CHUNKS * (T_PRODUCE + T_CONSUME)
        results = {
            "n_chunks": N_CHUNKS,
            "chunk_bytes": CHUNK_BYTES,
            "baseline_s": round(base_s, 3),
            "stream_s": round(stream_s, 3),
            "overlap_speedup": round(base_s / stream_s, 2),
            "serial_floor_s": round(floor, 3),
            "future_time_to_data_s": round(fut_latency, 4),
            "future_producer_s": round(fut_prod, 4),
            "overlap_beats_baseline": bool(stream_s < base_s),
        }
        record("fig13", results)
        assert results["overlap_beats_baseline"], results
    finally:
        store.close()
        unregister_store("fig13")
        kv.stop()


if __name__ == "__main__":
    run()
