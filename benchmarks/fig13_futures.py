"""Fig 13 (this repo): futures & streaming — producer/consumer overlap.

The paper's proxy model lets producers "communicate data unilaterally";
the follow-on patterns (arXiv:2407.01764 §futures/§streaming, and the
stream-of-proxies pipelines of arXiv:2410.12092) take that further:
communicate data *before it exists*.  This figure measures exactly that
against the classic produce→put→proxy→consume sequence:

* ``fig13.baseline.*`` — put-then-proxy: the producer computes every chunk,
  puts the batch, mints proxies; only then does the consumer start.  Wall
  clock is production + transfer + consumption, strictly serialized.
* ``fig13.future`` — one pre-data proxy (``Store.future``): the consumer is
  dispatched FIRST and parks in the channel's ``wait``; the producer's
  ``set_result`` releases it.  Measures the consumer's time-to-data beyond
  the producer's own compute (dispatch + transfer ride inside production).
* ``fig13.stream.*`` — ``stream_producer``/``stream_consumer``: chunks flow
  as they are produced, the consumer processes item ``i`` while the
  producer computes ``i+1``.  Wall clock approaches
  ``K * max(T_produce, T_consume)`` instead of the baseline's
  ``K * (T_produce + T_consume)``.

The produce/consume "compute" is a deterministic sleep so the overlap is
the measured quantity, not JIT noise.

* ``fig13.fanout.*`` — the PR 9 broker tier: ONE producer publishes
  1 MB events to 1/4/8 consumer groups.  ``proxy_on_publish`` resolves
  the payload in exactly one group (the others are ``payload=False``
  metadata taps — the paper's proxy-in-event pattern), so the data
  plane serves ~1× the payload bytes regardless of fanout;
  ``payload_in_event`` models classic pub/sub where every subscriber
  receives the full payload (G× served bytes).  A filtered tap rides
  along to confirm filtered events cost ZERO payload gets.
"""
from __future__ import annotations

import threading
import time

from benchmarks.util import emit, payload, record, tmpdir
from repro.core import Store, unregister_store
from repro.core.connectors import KVServerConnector
from repro.core.deploy import start_kvserver

N_CHUNKS = 12
CHUNK_BYTES = 250_000
T_PRODUCE = 0.03          # simulated per-chunk producer compute (s)
T_CONSUME = 0.03          # simulated per-chunk consumer compute (s)

FANOUT_EVENTS = 8         # events per fanout tier
FANOUT_BYTES = 1_000_000  # 1 MB payloads: the data plane dominates
FANOUT_GROUPS = (1, 4, 8)


def _chunks():
    return [payload(CHUNK_BYTES, seed=i) for i in range(N_CHUNKS)]


def run_baseline(store: Store) -> float:
    """produce all -> put batch -> proxy -> consume all (serialized)."""
    t0 = time.perf_counter()
    produced = []
    for c in _chunks():
        time.sleep(T_PRODUCE)
        produced.append(c)
    proxies = store.proxy_batch(produced, evict=True)
    for p in proxies:
        assert p.nbytes > 0          # resolve
        time.sleep(T_CONSUME)
    return time.perf_counter() - t0


def run_stream(store: Store) -> float:
    """producer streams as it computes; consumer overlaps processing."""
    topic = f"fig13-{time.monotonic_ns()}"
    t0 = time.perf_counter()

    def produce() -> None:
        with store.stream_producer(topic, ttl=60) as prod:
            for c in _chunks():
                time.sleep(T_PRODUCE)
                prod.append(c)

    t = threading.Thread(target=produce)
    t.start()
    n = 0
    for obj in store.stream_consumer(topic, timeout=30):
        assert obj.nbytes > 0
        time.sleep(T_CONSUME)
        n += 1
    t.join()
    assert n == N_CHUNKS
    return time.perf_counter() - t0


def run_future(store: Store) -> tuple[float, float]:
    """consumer dispatched BEFORE the data exists; measures its time-to-
    data beyond the producer's compute (should be ~transfer only)."""
    fut = store.future(timeout=30)
    proxy = fut.proxy()
    done = {}

    def consume() -> None:
        t0 = time.perf_counter()
        assert proxy.nbytes > 0      # parks in wait until set_result
        done["latency"] = time.perf_counter() - t0

    t = threading.Thread(target=consume)
    t.start()
    t_prod = N_CHUNKS * T_PRODUCE / 4
    time.sleep(t_prod)               # the producer's remaining compute
    fut.set_result(payload(CHUNK_BYTES))
    t.join()
    return done["latency"], t_prod


def _drain_group(conn, topic: str, group: str, *, payload: bool) -> None:
    evs = conn.stream_take_batch(topic, group, FANOUT_EVENTS,
                                 payload=payload)
    if len(evs) != FANOUT_EVENTS:
        raise RuntimeError(f"{group}: drained {len(evs)} events")
    if payload and any(ev.data is None for ev in evs):
        raise RuntimeError(f"{group}: missing payloads")
    conn.stream_ack(topic, group, [ev.seq for ev in evs])


def run_fanout(store: Store) -> dict:
    """ONE publish stream to N groups: proxy-on-publish (one resolving
    group + metadata taps) vs payload-in-event (every group resolves)."""
    conn = store.connector
    data = payload(FANOUT_BYTES, seed=7).tobytes()
    tiers: dict[str, dict] = {}
    for n_groups in FANOUT_GROUPS:
        for mode in ("proxy_on_publish", "payload_in_event"):
            topic = f"fan-{mode}-{n_groups}-{time.monotonic_ns()}"
            groups = [f"g{i}" for i in range(n_groups)]
            for g in groups:
                conn.stream_subscribe(topic, g)
            served0 = conn.stats()["payload_bytes_served"]
            # the publish leg is identical in both modes (the broker
            # stores ONE copy either way) — time the fanout DELIVERY:
            # every group drained, proxy mode resolving in exactly one
            for i in range(FANOUT_EVENTS):
                conn.stream_append(topic, data, meta={"i": i})
            t0 = time.perf_counter()
            for gi, g in enumerate(groups):
                resolve = mode == "payload_in_event" or gi == 0
                _drain_group(conn, topic, g, payload=resolve)
            dt = time.perf_counter() - t0
            served = conn.stats()["payload_bytes_served"] - served0
            eps = FANOUT_EVENTS / dt
            ratio = served / (FANOUT_EVENTS * FANOUT_BYTES)
            emit(f"fig13.fanout.{mode}.g{n_groups}", dt / FANOUT_EVENTS
                 * 1e6, f"served {ratio:.1f}x payload bytes",
                 req_per_s=eps)
            tiers[f"{mode}.g{n_groups}"] = {
                "events_per_s": round(eps, 1),
                "served_bytes_ratio": round(ratio, 2)}

    # filtered tap: events a group filters out cost ZERO payload gets
    topic = f"fan-filtered-{time.monotonic_ns()}"
    conn.stream_subscribe(topic, "tap",
                          filter={"key": "i", "op": "<", "value": 0})
    served0 = conn.stats()["payload_bytes_served"]
    for i in range(FANOUT_EVENTS):
        conn.stream_append(topic, data, meta={"i": i})
    if conn.stream_take_batch(topic, "tap", FANOUT_EVENTS,
                              payload=False):
        raise RuntimeError("filtered tap delivered events")
    filtered_gets = conn.stats()["payload_bytes_served"] - served0
    g8 = tiers["proxy_on_publish.g8"]
    b8 = tiers["payload_in_event.g8"]
    return {
        "events": FANOUT_EVENTS, "event_bytes": FANOUT_BYTES,
        "tiers": tiers,
        "g8_speedup": round(g8["events_per_s"] / b8["events_per_s"], 2),
        "g8_served_ratio_proxy": g8["served_bytes_ratio"],
        "g8_served_ratio_baseline": b8["served_bytes_ratio"],
        "filtered_payload_bytes": filtered_gets,
    }


def run(micro: bool = False) -> None:
    """``micro=True`` (the CI perf gate) runs ONLY the fanout tier —
    the overlap tiers are deterministic sleeps, nothing to gate."""
    d = tmpdir("fig13")
    kv = start_kvserver(d)
    store = Store("fig13", KVServerConnector(kv.host, kv.port))
    try:
        fanout = run_fanout(store)
        if micro:
            record("fig13", {"fanout": fanout})
            return
        base_s = run_baseline(store)
        stream_s = run_stream(store)
        fut_latency, fut_prod = run_future(store)

        emit("fig13.baseline.put_then_proxy", base_s * 1e6,
             f"{N_CHUNKS}x{CHUNK_BYTES}B serialized")
        emit("fig13.stream.overlap", stream_s * 1e6,
             f"{base_s / stream_s:.2f}x vs baseline")
        emit("fig13.future.time_to_data", fut_latency * 1e6,
             f"{max(fut_latency - fut_prod, 0) * 1e3:.1f}ms beyond producer")

        floor = N_CHUNKS * (T_PRODUCE + T_CONSUME)
        results = {
            "n_chunks": N_CHUNKS,
            "chunk_bytes": CHUNK_BYTES,
            "baseline_s": round(base_s, 3),
            "stream_s": round(stream_s, 3),
            "overlap_speedup": round(base_s / stream_s, 2),
            "serial_floor_s": round(floor, 3),
            "future_time_to_data_s": round(fut_latency, 4),
            "future_producer_s": round(fut_prod, 4),
            "overlap_beats_baseline": bool(stream_s < base_s),
            "fanout": fanout,
        }
        record("fig13", results)
        assert results["overlap_beats_baseline"], results
        assert fanout["filtered_payload_bytes"] == 0, fanout
        assert fanout["g8_served_ratio_proxy"] <= 1.5, fanout
        assert fanout["g8_speedup"] >= 3.0, fanout
    finally:
        store.close()
        unregister_store("fig13")
        kv.stop()


if __name__ == "__main__":
    run()
