"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (benchmarks/util.emit) per row.
Run:  PYTHONPATH=src python -m benchmarks.run [--only fig5,table2]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("fig5", "benchmarks.fig5_faas_rtt"),
    ("fig6", "benchmarks.fig6_inmemory"),
    ("fig7", "benchmarks.fig7_workflow"),
    ("fig8", "benchmarks.fig8_endpoint_clients"),
    ("fig9", "benchmarks.fig9_endpoint_peering"),
    ("table2", "benchmarks.table2_defect"),
    ("fig10", "benchmarks.fig10_federated"),
    ("fig11", "benchmarks.fig11_steering"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig5,table2")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for tag, module in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            __import__(module, fromlist=["run"]).run()
            print(f"# {tag} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(tag)
            print(f"# {tag} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
