"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (benchmarks/util.emit) per row and
writes ``BENCH_<tag>.json`` next to the repo root for every figure run, so
the perf trajectory is recorded PR over PR (rows + any structured results
the figure stashed via ``benchmarks.util.record``).

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig5,table2]

``--sanitize`` smoke-checks the fig6 micro tier under ``REPRO_SANITIZE=1``
(poison-on-free, quarantine, refcount ledger all hot) to bound the
sanitizer's overhead; results land in ``BENCH_fig6_sanitize.json`` so the
overhead trajectory is recorded without touching the perf-gate baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback
from pathlib import Path

from benchmarks import util

MODULES = [
    ("fig5", "benchmarks.fig5_faas_rtt"),
    ("fig6", "benchmarks.fig6_inmemory"),
    ("fig7", "benchmarks.fig7_workflow"),
    ("fig8", "benchmarks.fig8_endpoint_clients"),
    ("fig9", "benchmarks.fig9_endpoint_peering"),
    ("table2", "benchmarks.table2_defect"),
    ("fig10", "benchmarks.fig10_federated"),
    ("fig11", "benchmarks.fig11_steering"),
    ("fig12", "benchmarks.fig12_ownership"),
    ("fig13", "benchmarks.fig13_futures"),
    ("serve", "benchmarks.fig14_serving"),
    ("fabric", "benchmarks.fig15_fabric"),
    ("durability", "benchmarks.fig16_durability"),
]

_ROOT = Path(__file__).resolve().parents[1]


def _dump(tag: str, rows: list[dict], elapsed: float,
          suffix: str = "") -> None:
    out = {
        "figure": tag + suffix,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "elapsed_s": round(elapsed, 2),
        "rows": rows,   # structured dicts: numeric us_per_call / mb_per_s
        "results": util.RESULTS.pop(tag, {}),  # modules record by bare tag
    }
    path = _ROOT / f"BENCH_{tag}{suffix}.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig5,table2")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the fig6 micro tier under REPRO_SANITIZE=1 "
                         "to bound sanitizer overhead (writes "
                         "BENCH_fig6_sanitize.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    suffix = ""
    if args.sanitize:
        # sanitize smoke: restrict to the micro tier — the point is the
        # relative overhead of the hot path, not a full figure sweep
        os.environ["REPRO_SANITIZE"] = "1"
        only = {"fig6"} if only is None else (only & {"fig6"} or {"fig6"})
        suffix = "_sanitize"

    print("name,us_per_call,derived")
    failures = []
    for tag, module in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        n_rows = len(util.ROWS)
        try:
            __import__(module, fromlist=["run"]).run()
            elapsed = time.time() - t0
            _dump(tag, util.ROWS[n_rows:], elapsed, suffix)
            print(f"# {tag}{suffix} done in {elapsed:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(tag)
            print(f"# {tag} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
