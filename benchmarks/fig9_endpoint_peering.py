"""Fig 9 analog: endpoint-to-endpoint request times vs payload size.

Three scenarios mirror the paper: same-site peering (no throttle = the
Theta-Theta baseline), inter-site peering with the measured aiortc regime
(~80 Mbps + WAN RTT, §5.3.2), and the "Redis+SSH" comparison (direct KV
server with the same injected WAN latency, one hop fewer) — reproducing the
paper's observed crossover: the extra endpoint hop dominates locally, the
channel ceiling dominates at large payloads.
"""
from __future__ import annotations

import time

from benchmarks.util import emit, fmt_bytes, payload, time_call, tmpdir
from repro.core import join_frame, serialize
from repro.core.connectors import EndpointConnector, KVServerConnector
from repro.core.deploy import start_endpoint, start_kvserver, start_relay

SIZES = [10_000, 1_000_000, 10_000_000]
WAN_RTT = 0.03                      # ~30 ms cross-site
AIORTC_BPS = 80e6 / 8               # the paper's 80 Mbps ceiling


class _LatencyKV(KVServerConnector):
    """Redis-over-SSH-tunnel analog: same WAN latency, direct channel."""

    def __init__(self, host, port, rtt):
        super().__init__(host, port)
        self.rtt = rtt

    def get(self, key):
        time.sleep(self.rtt / 2)
        return super().get(key)

    def put(self, blob):
        time.sleep(self.rtt / 2)
        return super().put(blob)


def run() -> None:
    d = tmpdir("fig9")
    relay = start_relay(d)
    # same-site pair
    ep_a = start_endpoint(d, relay.address, name="a")
    ep_b = start_endpoint(d, relay.address, name="b")
    # "inter-site" pair with the aiortc WAN regime
    ep_c = start_endpoint(d, relay.address, name="c",
                          throttle_bps=AIORTC_BPS, throttle_rtt=WAN_RTT)
    ep_e = start_endpoint(d, relay.address, name="e",
                          throttle_bps=AIORTC_BPS, throttle_rtt=WAN_RTT)
    kv = start_kvserver(d)

    ca = EndpointConnector(address=ep_a.address)
    cc = EndpointConnector(address=ep_c.address)
    for size in SIZES:
        blob = join_frame(serialize(payload(size)))

        # same-site: B stores, A fetches via peer channel
        cb = EndpointConnector(address=ep_b.address)
        key = cb.put(blob)
        t = time_call(lambda: ca.get(key))
        emit(f"fig9.same-site.peer.{fmt_bytes(size)}", t * 1e6, "endpoint")
        cb.evict(key)

        # inter-site: E stores, C fetches through the throttled channel
        ce = EndpointConnector(address=ep_e.address)
        key = ce.put(blob)
        t = time_call(lambda: cc.get(key), reps=2)
        emit(f"fig9.inter-site.peer.{fmt_bytes(size)}", t * 1e6,
             "aiortc-regime")
        ce.evict(key)

        # Redis+SSH analog: direct KV with injected WAN rtt
        lkv = _LatencyKV(kv.host, kv.port, WAN_RTT)
        key = lkv.put(blob)
        t = time_call(lambda: lkv.get(key), reps=2)
        emit(f"fig9.inter-site.redis-ssh.{fmt_bytes(size)}", t * 1e6,
             "direct-1-hop")
        lkv.evict(key)

    for h in (ep_a, ep_b, ep_c, ep_e, relay, kv):
        h.stop()


if __name__ == "__main__":
    run()
