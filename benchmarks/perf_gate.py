"""CI perf-regression smoke gate over the fig6 micro tier + serving.

Runs ``benchmarks.fig6_inmemory.run(micro=True)`` (two sizes, every
connector, a few seconds) and compares the shm / kvserver throughput rows
against the committed ``BENCH_fig6.json`` baseline: the gate **fails**
when a gated row's ``mb_per_s`` drops more than ``PERF_GATE_TOLERANCE``
(default 30%) below baseline.  The other connectors are reported but not
gated — file and socket numbers swing with runner disk/network weather;
shm and kvserver are the data plane this repo owns.

When a committed ``BENCH_serve.json`` baseline exists, the gate also runs
``benchmarks.fig14_serving.run(micro=True)`` (one proxy-stream round at
batch 8) and applies the same tolerance to the ``fig14.proxy_stream.b8``
row's ``req_per_s`` — the serving engine's end-to-end throughput.  Skip
just this half with ``PERF_GATE_SKIP_SERVE=1`` (it JIT-compiles the tiny
model, ~20 s on a cold runner).

When a committed ``BENCH_fabric.json`` baseline exists, the gate also
runs ``benchmarks.fig15_fabric.run(micro=True)`` (1- and 4-shard
aggregate rows + the kill-a-shard recovery row) and gates the sharded
fabric both ways: the 4-shard aggregate ``mb_per_s`` row is FLOORED at
``1 - tolerance`` of baseline, and the recovery time is CAPPED at
``(1 + 2 * tolerance)`` of the baseline ``recovery_ms`` (recovery is a
latency, so the cap widens twice as fast — a kill/reconnect cycle on a
shared runner jitters more than a throughput sample).  Skip just this
half with ``PERF_GATE_SKIP_FABRIC=1``.

When a committed ``BENCH_fig13.json`` baseline carries the fanout rows,
the gate also runs ``benchmarks.fig13_futures.run(micro=True)`` (the
broker fanout tier only: one producer, 8 consumer groups, 1 MB events)
and floors the ``fig13.fanout.proxy_on_publish.g8`` row's ``req_per_s``
at ``1 - tolerance`` of baseline; it also re-checks the proxy-on-publish
invariant outright — served payload bytes at 8 groups must stay ~1x the
published bytes (a ratio above 1.5 means taps started resolving payloads
and fanout cost is back to O(groups), tolerance does not excuse it).
Skip just this half with ``PERF_GATE_SKIP_FANOUT=1``.

When a committed ``BENCH_durability.json`` baseline exists, the gate
also runs ``benchmarks.fig16_durability.run(micro=True)`` (chain-vs-
legacy put egress, kill-1-of-4 zero-loss, stream failover) and checks
the durability invariants outright — these are correctness bars, not
tolerance-scaled: ``lost_puts`` and ``skipped_seqs`` must be 0, the
redelivery ratio must stay under a hard 1.5x cap, and the chain-put
client egress must stay at or under 0.75x the legacy fanout at R=2
(the tentpole claim is ~0.5x).  Skip just this half with
``PERF_GATE_SKIP_DURABILITY=1`` (it SIGKILLs shards, ~10 s).

Opt-outs for slow or shared runners:

* ``PERF_GATE_SKIP=1``      — skip entirely (exit 0).
* ``PERF_GATE_TOLERANCE=.5`` — widen the allowed drop.

Baseline rows predating the numeric schema (string ``us_per_call``, no
``mb_per_s``) are skipped with a note rather than failed, so the gate is
safe to enable before the first regenerated baseline lands.

Run:  PYTHONPATH=src python -m benchmarks.perf_gate
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

GATED_PREFIXES = ("fig6.shm.", "fig6.kvserver.")
SERVE_GATED_ROW = "fig14.proxy_stream.b8"
FABRIC_GATED_ROW = "fig15.agg.4shard.977KB"
FABRIC_RECOVERY_ROW = "fig15.recovery.kill1of4"
FANOUT_GATED_ROW = "fig13.fanout.proxy_on_publish.g8"
FANOUT_RATIO_CAP = 1.5
DURABILITY_EGRESS_CAP = 0.75    # chain put tx vs legacy fanout at R=2
DURABILITY_REDELIVERY_CAP = 1.5
_ROOT = Path(__file__).resolve().parents[1]


def _baseline_rows(bench: str = "fig6") -> dict[str, dict]:
    path = _ROOT / f"BENCH_{bench}.json"
    if not path.exists():
        return {}
    rows = json.loads(path.read_text()).get("rows", [])
    return {r.get("name"): r for r in rows if isinstance(r, dict)}


def main() -> int:
    if os.environ.get("PERF_GATE_SKIP"):
        print("perf gate: skipped (PERF_GATE_SKIP set)")
        return 0
    tolerance = float(os.environ.get("PERF_GATE_TOLERANCE", "0.30"))
    baseline = _baseline_rows()
    if not baseline:
        print("perf gate: no BENCH_fig6.json baseline; nothing to compare")
        return 0

    from benchmarks import util
    from benchmarks.fig6_inmemory import run

    def _measure() -> dict[str, float]:
        n0 = len(util.ROWS)
        run(micro=True)
        return {r["name"]: r["mb_per_s"] for r in util.ROWS[n0:]
                if r.get("mb_per_s") is not None}

    current = _measure()
    # one retry on failure, keeping the better reading per row: a noisy-
    # neighbor burst during a ~5 s micro run must not fail the gate
    if _evaluate(current, baseline, tolerance, verbose=False):
        print("perf gate: regression readings; retrying once "
              "(best-of-two per row)...")
        for name, mbps in _measure().items():
            current[name] = max(current.get(name, 0.0), mbps)
    failures = _evaluate(current, baseline, tolerance)
    failures += _gate_serve(tolerance)
    failures += _gate_fabric(tolerance)
    failures += _gate_fanout(tolerance)
    failures += _gate_durability(tolerance)
    if not failures:
        print("perf gate: ok")
        return 0
    print("\nperf gate FAILED:\n  " + "\n  ".join(failures))
    print("(slow runner? opt out with PERF_GATE_SKIP=1 or widen "
          "PERF_GATE_TOLERANCE)")
    return 1


def _gate_serve(tolerance: float) -> list[str]:
    """Serve-throughput row: req/s of the batch-8 proxy-stream round vs
    the committed BENCH_serve.json baseline."""
    if os.environ.get("PERF_GATE_SKIP_SERVE"):
        print("perf gate: serve half skipped (PERF_GATE_SKIP_SERVE set)")
        return []
    base = _baseline_rows("serve").get(SERVE_GATED_ROW, {})
    base_rps = base.get("req_per_s")
    if not isinstance(base_rps, (int, float)):
        print("perf gate: no BENCH_serve.json req_per_s baseline; "
              "serving not gated")
        return []

    from benchmarks import util
    from benchmarks.fig14_serving import run

    def _measure() -> float:
        n0 = len(util.ROWS)
        run(micro=True)
        rows = {r["name"]: r for r in util.ROWS[n0:]}
        return float(rows[SERVE_GATED_ROW].get("req_per_s", 0.0))

    rps = _measure()
    floor = (1.0 - tolerance) * base_rps
    if rps < floor:            # one retry, best-of-two (noisy neighbors)
        rps = max(rps, _measure())
    status = "ok" if rps >= floor else "FAIL"
    print(f"  {SERVE_GATED_ROW}: {rps:.1f} req/s vs baseline "
          f"{base_rps:.1f} (floor {floor:.1f}) [{status}]")
    if status == "FAIL":
        return [f"{SERVE_GATED_ROW}: {rps:.1f} req/s < {floor:.1f} req/s "
                f"({tolerance:.0%} below baseline {base_rps:.1f})"]
    return []


def _gate_fabric(tolerance: float) -> list[str]:
    """Sharded-fabric rows: floor the 4-shard aggregate throughput and cap
    the kill-a-shard recovery time vs the committed BENCH_fabric.json."""
    if os.environ.get("PERF_GATE_SKIP_FABRIC"):
        print("perf gate: fabric half skipped (PERF_GATE_SKIP_FABRIC set)")
        return []
    rows = _baseline_rows("fabric")
    base_mbps = rows.get(FABRIC_GATED_ROW, {}).get("mb_per_s")
    path = _ROOT / "BENCH_fabric.json"
    base_rec_ms = None
    if path.exists():
        base_rec_ms = json.loads(path.read_text()).get(
            "results", {}).get("recovery_ms")
    if not isinstance(base_mbps, (int, float)):
        print("perf gate: no BENCH_fabric.json baseline; fabric not gated")
        return []

    from benchmarks import util
    from benchmarks.fig15_fabric import run

    def _measure() -> tuple[float, float | None]:
        n0 = len(util.ROWS)
        run(micro=True)
        rows_now = {r["name"]: r for r in util.ROWS[n0:]}
        mbps = float(rows_now.get(FABRIC_GATED_ROW, {}).get("mb_per_s", 0.0))
        rec = rows_now.get(FABRIC_RECOVERY_ROW, {}).get("us_per_call")
        return mbps, (float(rec) / 1e3 if rec is not None else None)

    mbps, rec_ms = _measure()
    floor = (1.0 - tolerance) * base_mbps
    # recovery is a latency: cap widens twice as fast as the throughput
    # tolerance (kill + reconnect cycles jitter hard on shared runners)
    cap = ((1.0 + 2 * tolerance) * base_rec_ms
           if isinstance(base_rec_ms, (int, float)) else None)
    if mbps < floor or (cap is not None and rec_ms is not None
                        and rec_ms > cap):
        m2, r2 = _measure()        # one retry, best-of-two (noise)
        mbps = max(mbps, m2)
        if r2 is not None:
            rec_ms = r2 if rec_ms is None else min(rec_ms, r2)
    failures: list[str] = []
    status = "ok" if mbps >= floor else "FAIL"
    print(f"  {FABRIC_GATED_ROW}: {mbps:.0f} MB/s vs baseline "
          f"{base_mbps:.0f} (floor {floor:.0f}) [{status}]")
    if status == "FAIL":
        failures.append(f"{FABRIC_GATED_ROW}: {mbps:.0f} MB/s < "
                        f"{floor:.0f} MB/s ({tolerance:.0%} below "
                        f"baseline {base_mbps:.0f})")
    if cap is not None and rec_ms is not None:
        status = "ok" if rec_ms <= cap else "FAIL"
        print(f"  {FABRIC_RECOVERY_ROW}: {rec_ms:.1f} ms vs baseline "
              f"{base_rec_ms:.1f} (cap {cap:.1f}) [{status}]")
        if status == "FAIL":
            failures.append(f"{FABRIC_RECOVERY_ROW}: {rec_ms:.1f} ms > "
                            f"cap {cap:.1f} ms (baseline "
                            f"{base_rec_ms:.1f} ms)")
    return failures


def _gate_fanout(tolerance: float) -> list[str]:
    """Broker-fanout row: delivery events/s of the 8-group
    proxy-on-publish drain vs the committed BENCH_fig13.json baseline,
    plus the served-bytes invariant (payload crosses the data plane ~1x
    per fanout, NOT once per group — a hard cap, not tolerance-scaled)."""
    if os.environ.get("PERF_GATE_SKIP_FANOUT"):
        print("perf gate: fanout half skipped (PERF_GATE_SKIP_FANOUT set)")
        return []
    base = _baseline_rows("fig13").get(FANOUT_GATED_ROW, {})
    base_eps = base.get("req_per_s")
    if not isinstance(base_eps, (int, float)):
        print("perf gate: no BENCH_fig13.json fanout baseline; "
              "fanout not gated")
        return []

    from benchmarks import util
    from benchmarks.fig13_futures import run

    def _measure() -> tuple[float, float]:
        n0 = len(util.ROWS)
        run(micro=True)
        rows = {r["name"]: r for r in util.ROWS[n0:]}
        eps = float(rows[FANOUT_GATED_ROW].get("req_per_s", 0.0))
        fanout = util.RESULTS.get("fig13", {}).get("fanout", {})
        return eps, float(fanout.get("g8_served_ratio_proxy", 0.0))

    eps, ratio = _measure()
    floor = (1.0 - tolerance) * base_eps
    if eps < floor:            # one retry, best-of-two (noisy neighbors)
        e2, ratio = _measure()
        eps = max(eps, e2)
    failures: list[str] = []
    status = "ok" if eps >= floor else "FAIL"
    print(f"  {FANOUT_GATED_ROW}: {eps:.1f} ev/s vs baseline "
          f"{base_eps:.1f} (floor {floor:.1f}) [{status}]")
    if status == "FAIL":
        failures.append(f"{FANOUT_GATED_ROW}: {eps:.1f} ev/s < "
                        f"{floor:.1f} ev/s ({tolerance:.0%} below "
                        f"baseline {base_eps:.1f})")
    status = "ok" if ratio <= FANOUT_RATIO_CAP else "FAIL"
    print(f"  fig13.fanout served-bytes ratio at 8 groups: {ratio:.2f}x "
          f"(cap {FANOUT_RATIO_CAP}x) [{status}]")
    if status == "FAIL":
        failures.append(f"fanout served-bytes ratio {ratio:.2f}x > "
                        f"{FANOUT_RATIO_CAP}x: proxy-on-publish is "
                        f"resolving payloads in more than one group")
    return failures


def _gate_durability(tolerance: float) -> list[str]:
    """Durability invariants: zero committed puts lost, zero skipped
    stream seqs, redelivery ratio under a hard cap, and chain-put client
    egress at or under ``DURABILITY_EGRESS_CAP`` of the legacy fanout.
    These are correctness bars — ``tolerance`` does not widen them."""
    if os.environ.get("PERF_GATE_SKIP_DURABILITY"):
        print("perf gate: durability half skipped "
              "(PERF_GATE_SKIP_DURABILITY set)")
        return []
    if not (_ROOT / "BENCH_durability.json").exists():
        print("perf gate: no BENCH_durability.json baseline; "
              "durability not gated")
        return []

    from benchmarks import util
    from benchmarks.fig16_durability import run

    run(micro=True)
    res = util.RESULTS.get("durability", {})
    failures: list[str] = []
    checks = [
        ("lost_puts", res.get("lost_puts"), 0,
         "committed chain-replicated puts lost across a shard kill"),
        ("skipped_seqs", res.get("skipped_seqs"), 0,
         "committed stream events skipped across failover"),
    ]
    for name, value, bar, what in checks:
        status = "ok" if value == bar else "FAIL"
        print(f"  fig16.{name}: {value} (must be {bar}) [{status}]")
        if status == "FAIL":
            failures.append(f"fig16.{name}: {value} {what} (must be {bar})")
    ratio = float(res.get("redelivery_ratio") or 0.0)
    status = "ok" if 0 < ratio <= DURABILITY_REDELIVERY_CAP else "FAIL"
    print(f"  fig16.redelivery_ratio: {ratio:.2f}x "
          f"(cap {DURABILITY_REDELIVERY_CAP}x) [{status}]")
    if status == "FAIL":
        failures.append(f"fig16.redelivery_ratio {ratio:.2f}x outside "
                        f"(0, {DURABILITY_REDELIVERY_CAP}]: failover "
                        f"redelivery is no longer bounded")
    egress = float(res.get("egress_ratio_chain_vs_legacy") or 0.0)
    status = "ok" if 0 < egress <= DURABILITY_EGRESS_CAP else "FAIL"
    print(f"  fig16.egress_ratio_chain_vs_legacy: {egress:.2f}x "
          f"(cap {DURABILITY_EGRESS_CAP}x) [{status}]")
    if status == "FAIL":
        failures.append(f"fig16.egress chain/legacy ratio {egress:.2f}x "
                        f"outside (0, {DURABILITY_EGRESS_CAP}]: the chain "
                        f"path is no longer saving client upload bandwidth")
    if res.get("dlq_count") != 1:
        failures.append(f"fig16.dlq_count: {res.get('dlq_count')} poison "
                        f"events dead-lettered (must be 1)")
        print(f"  fig16.dlq_count: {res.get('dlq_count')} (must be 1) "
              f"[FAIL]")
    else:
        print("  fig16.dlq_count: 1 (must be 1) [ok]")
    return failures


def _evaluate(current: dict[str, float], baseline: dict[str, dict],
              tolerance: float, *, verbose: bool = True) -> list[str]:
    failures: list[str] = []
    for name, mbps in sorted(current.items()):
        base = baseline.get(name)
        gated = name.startswith(GATED_PREFIXES)
        if base is None:
            if verbose:
                print(f"  {name}: {mbps:.0f} MB/s (no baseline row)")
            continue
        base_mbps = base.get("mb_per_s")
        if not isinstance(base_mbps, (int, float)):
            if verbose:
                print(f"  {name}: {mbps:.0f} MB/s (baseline predates "
                      f"numeric schema; skipped)")
            continue
        floor = (1.0 - tolerance) * base_mbps
        status = "ok" if mbps >= floor else ("FAIL" if gated else "warn")
        if verbose:
            print(f"  {name}: {mbps:.0f} MB/s vs baseline {base_mbps:.0f} "
                  f"(floor {floor:.0f}) [{status}]")
        if status == "FAIL":
            failures.append(
                f"{name}: {mbps:.0f} MB/s < {floor:.0f} MB/s "
                f"({tolerance:.0%} below baseline {base_mbps:.0f})")
    return failures


if __name__ == "__main__":
    sys.exit(main())
